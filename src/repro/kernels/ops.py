"""Jit'd dispatching wrappers for the Pallas kernels.

On TPU these call the Mosaic-compiled kernels; on CPU (this container) they
run ``interpret=True`` so the exact kernel bodies are validated against the
ref.py oracles. ``use_pallas()`` is the single switch the model layer
consults.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import routing as _rt
from repro.kernels import ssd as _ssd
from repro.kernels import swiglu as _sw


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale", "interpret"))
def flash_attention_op(
    q, k, v, q_pos, kv_pos, *, causal=True, window=0, scale=None, interpret=None
):
    interp = on_cpu() if interpret is None else interpret
    return _fa.flash_attention(
        q, k, v, q_pos, kv_pos, causal=causal, window=window, scale=scale, interpret=interp
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_op(x, loglam, dt, Bm, Cm, *, interpret=None):
    interp = on_cpu() if interpret is None else interpret
    return _ssd.ssd_intra_chunk(x, loglam, dt, Bm, Cm, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def swiglu_op(x, w_gate, w_up, w_down, *, interpret=None):
    interp = on_cpu() if interpret is None else interpret
    return _sw.swiglu(x, w_gate, w_up, w_down, interpret=interp)


def gather_rows_op(x, idx, *, interpret=None):
    """Fused MoD row-gather (core/routing.py "pallas" backend dispatch)."""
    interp = on_cpu() if interpret is None else interpret
    return _rt.gather_rows(x, idx, interpret=interp)


def scatter_add_rows_op(x, idx, delta, gate, *, interpret=None):
    """Fused MoD gated scatter-add (core/routing.py "pallas" backend combine)."""
    interp = on_cpu() if interpret is None else interpret
    return _rt.scatter_add_rows(x, idx, delta, gate, interpret=interp)
