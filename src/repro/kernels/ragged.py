"""Pallas kernels for the ragged flat-token serving layout.

A mixed prefill+decode engine step carries its work as one flat token
stream ``(total_tokens, ...)`` segmented by ``input_row_offsets`` — segment
``s`` owns rows ``[row_offsets[s], row_offsets[s+1])`` and belongs to one
serving slot (``seg_slot[s]``).  Padding exists only as a bounded tail
behind ``row_offsets[-1]``, never between segments, so compute follows
tokens instead of a padded ``(B, S)`` rectangle (the MoD thesis applied to
the batch dimension).  Three kernel families:

- ``ragged_paged_flash_attention``: flash attention whose queries are the
  flat stream and whose K/V is read *directly out of the block-paged pool*
  — the page table rides the grid as a scalar-prefetch operand (the
  ``kernels/paged.py`` trick) so grid step ``(s, h, i)`` DMAs exactly one
  physical page of segment ``s``'s slot.  No per-slot ``(ctx,)`` view is
  ever materialized.
- ``ragged_gather_rows`` / ``ragged_scatter_add_rows``: the MoD dispatch
  pair (paper Eq. 1) over the flat stream.  ``idx`` holds *flat* row
  indices grouped per segment ``(n_seg, k)``; ``-1`` marks masked
  selections (a segment shorter than its top-k capacity), which the
  one-hot matmuls drop exactly — no clamp-and-hope writes into a
  neighbouring segment.
- ``ragged_paged_scatter_rows``: the mixed step's write-back — ``W``
  token rows (decode rows + every prefill token of the step) land in
  their slots' pages in one pass; rows with ``valid=False`` are routed to
  a caller-supplied dump page (the pool's scratch page) so shapes stay
  static.

All kernels run under ``interpret=True`` on CPU (validated against the
``kernels/ref.py`` oracles in tests/test_ragged.py) and lower to Mosaic on
TPU.  Because the attention kernel replays ``_flash_kernel``'s op sequence
per page (block_kv = page_size) and the dispatch kernels are one-hot
matmuls over unique indices, their f32 outputs are bit-for-bit equal to
the padded-path formulations they replace — pinned, not just allclose'd,
in the tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF, _vmem
from repro.kernels.routing import _block_s


# ---------------------------------------------------------------------------
# Ragged paged flash attention
# ---------------------------------------------------------------------------


def _ragged_flash_kernel(
    offs_ref,  # (n_seg+1,) scalar-prefetch
    slot_ref,  # (n_seg,)   scalar-prefetch
    tbl_ref,  # (B, P)      scalar-prefetch
    qpos_ref,  # (1, T+C)
    q_ref,  # (1, T+C, 1, hd) — head axis selected by the BlockSpec
    kpos_ref,  # (1, p)
    k_ref,  # (1, p, 1, hd)
    v_ref,  # (1, p, 1, hd)
    o_ref,  # (1, 1, C, hd)
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    n_pages: int,
    seg_cap: int,
):
    s_id = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = offs_ref[s_id]
    seg_len = offs_ref[s_id + 1] - start
    q = q_ref[0, pl.dslice(start, seg_cap), 0, :].astype(jnp.float32)  # (C, hd)
    qp = qpos_ref[0, pl.dslice(start, seg_cap)]  # (C,)
    kp = kpos_ref[0]  # (p,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (p, hd)
    v = v_ref[0, :, 0, :]  # (p, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (C, p)
    # rows past this segment's length hold the *next* segment's tokens —
    # mask them here; the wrapper drops their (garbage-zero) output rows
    in_seg = jax.lax.broadcasted_iota(jnp.int32, (seg_cap, k.shape[0]), 0) < seg_len
    valid = in_seg & (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window > 0:
        valid &= qp[:, None] - kp[None, :] < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(i == n_pages - 1)
    def _finish():
        l_fin = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l_fin[:, None]).astype(o_ref.dtype)


def _ragged_flash_quant_kernel(
    offs_ref,  # (n_seg+1,) scalar-prefetch
    slot_ref,  # (n_seg,)   scalar-prefetch
    tbl_ref,  # (B, P)      scalar-prefetch
    qpos_ref,  # (1, T+C)
    q_ref,  # (1, T+C, 1, hd)
    kpos_ref,  # (1, p)
    k_ref,  # (1, p, 1, hd) narrow (int8 | fp8)
    ks_ref,  # (1, p, 1) f32 per-(page-row, kv-head) scales
    v_ref,  # (1, p, 1, hd) narrow
    vs_ref,  # (1, p, 1) f32
    o_ref,  # (1, 1, C, hd)
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    n_pages: int,
    seg_cap: int,
):
    """`_ragged_flash_kernel` with fused dequantization: the narrow K/V
    page is widened in VMEM right after the DMA (one f32 scale per page
    row per kv head — the same multiply the quantized oracle uses), so
    quantized KV never crosses HBM at full width."""
    s_id = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = offs_ref[s_id]
    seg_len = offs_ref[s_id + 1] - start
    q = q_ref[0, pl.dslice(start, seg_cap), 0, :].astype(jnp.float32)  # (C, hd)
    qp = qpos_ref[0, pl.dslice(start, seg_cap)]  # (C,)
    kp = kpos_ref[0]  # (p,)
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0][:, None]  # (p, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0][:, None]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (C, p)
    in_seg = jax.lax.broadcasted_iota(jnp.int32, (seg_cap, k.shape[0]), 0) < seg_len
    valid = in_seg & (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window > 0:
        valid &= qp[:, None] - kp[None, :] < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(i == n_pages - 1)
    def _finish():
        l_fin = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l_fin[:, None]).astype(o_ref.dtype)


def flat_segment_ids(row_offsets: jax.Array, total: int) -> jax.Array:
    """seg_id[t] for every flat row: the segment owning token t (rows past
    ``row_offsets[-1]`` map to the last segment; callers mask them)."""
    t = jnp.arange(total, dtype=jnp.int32)
    n_seg = row_offsets.shape[0] - 1
    return jnp.clip(
        jnp.searchsorted(row_offsets, t, side="right") - 1, 0, n_seg - 1
    ).astype(jnp.int32)


def ragged_paged_flash_attention(
    q: jax.Array,  # (T, nq, hd) flat query stream
    k_pages: jax.Array,  # (N, p, nkv, hd)
    v_pages: jax.Array,  # (N, p, nkv, hd)
    pos_pages: jax.Array,  # (N, p) int32 absolute positions; -1 = empty slot
    table: jax.Array,  # (B, P) int32 per-slot page table
    row_offsets: jax.Array,  # (n_seg+1,) int32, non-decreasing, starts at 0
    seg_slot: jax.Array,  # (n_seg,) int32 — the slot whose pages segment s reads
    q_pos: jax.Array,  # (T,) int32 absolute positions; -1 = invalid row
    *,
    seg_cap: int,  # static bound: every segment has <= seg_cap tokens
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    interpret: bool = False,
    k_scales: Optional[jax.Array] = None,  # (N, p, nkv) f32 — quantized KV
    v_scales: Optional[jax.Array] = None,
) -> jax.Array:  # (T, nq, hd); rows past row_offsets[-1] are zero
    T, nq, hd = q.shape
    N, p, nkv, _ = k_pages.shape
    B, P = table.shape
    n_seg = row_offsets.shape[0] - 1
    assert nq % nkv == 0
    assert (k_scales is None) == (v_scales is None)
    quant = k_scales is not None
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    C = int(seg_cap)

    # pad the flat stream by one segment capacity so the in-kernel dynamic
    # slice at the last segment never reads out of bounds
    qp2 = jnp.pad(q_pos.astype(jnp.int32), (0, C), constant_values=-1)[None]
    qf = jnp.pad(q, ((0, C), (0, 0), (0, 0)))[None]  # (1, T+C, nq, hd)

    kv_spec = pl.BlockSpec(
        (1, p, 1, hd),
        lambda s, h, i, offs, slot, tbl, _nkv=nkv, _nq=nq: (
            tbl[slot[s], i], 0, h * _nkv // _nq, 0,
        ),
    )
    sc_spec = pl.BlockSpec(
        (1, p, 1),
        lambda s, h, i, offs, slot, tbl, _nkv=nkv, _nq=nq: (
            tbl[slot[s], i], 0, h * _nkv // _nq,
        ),
    )
    in_specs = [
        pl.BlockSpec((1, T + C), lambda s, h, i, offs, slot, tbl: (0, 0)),
        pl.BlockSpec((1, T + C, 1, hd), lambda s, h, i, offs, slot, tbl: (0, 0, h, 0)),
        pl.BlockSpec(
            (1, p), lambda s, h, i, offs, slot, tbl: (tbl[slot[s], i], 0)
        ),
        kv_spec,
        *([sc_spec] if quant else []),
        kv_spec,
        *([sc_spec] if quant else []),
    ]
    operands = [pos_pages, k_pages]
    if quant:
        operands.append(k_scales.astype(jnp.float32))
    operands.append(v_pages)
    if quant:
        operands.append(v_scales.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_seg, nq, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, C, hd), lambda s, h, i, offs, slot, tbl: (s, h, 0, 0)),
        scratch_shapes=[
            _vmem((C, hd), jnp.float32),
            _vmem((C, 1), jnp.float32),
            _vmem((C, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _ragged_flash_quant_kernel if quant else _ragged_flash_kernel,
        scale=float(scale), causal=bool(causal), window=int(window),
        n_pages=P, seg_cap=C,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_seg, nq, C, hd), q.dtype),
        interpret=interpret,
    )(row_offsets.astype(jnp.int32), seg_slot.astype(jnp.int32),
      table.astype(jnp.int32), qp2, qf, *operands)

    # scatter the (n_seg, C) segment rows back onto the flat stream
    seg_id = flat_segment_ids(row_offsets, T)
    local = jnp.clip(jnp.arange(T, dtype=jnp.int32) - row_offsets[seg_id], 0, C - 1)
    flat = out[seg_id, :, local, :]  # (T, nq, hd)
    live = jnp.arange(T) < row_offsets[-1]
    return jnp.where(live[:, None, None], flat, 0)


# ---------------------------------------------------------------------------
# Ragged MoD dispatch: flat-stream gather / gated scatter-add
# ---------------------------------------------------------------------------


def _ragged_gather_kernel(idx_ref, x_ref, o_ref, acc_ref, *, bs: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[0, :]  # (k,) flat row ids; -1 never matches any row
    k = idx.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (k, bs), 1) + j * bs
    P = (rows == idx[:, None]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        P, x_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def ragged_gather_rows(
    x: jax.Array,  # (T, D) flat stream
    idx: jax.Array,  # (n_seg, k) int32 flat indices; -1 = masked (zero row)
    *,
    interpret: bool = False,
    block_s: int = 256,
) -> jax.Array:  # (n_seg, k, D)
    T, D = x.shape
    n_seg, k = idx.shape
    bs = _block_s(T, block_s)
    n_blocks = T // bs
    kernel = functools.partial(_ragged_gather_kernel, bs=bs, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(n_seg, n_blocks),
        in_specs=[
            pl.BlockSpec((1, k), lambda s, j: (s, 0)),
            pl.BlockSpec((bs, D), lambda s, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, D), lambda s, j: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_seg, k, D), x.dtype),
        scratch_shapes=[_vmem((k, D), jnp.float32)],
        interpret=interpret,
    )(idx.astype(jnp.int32), x)


def _ragged_scatter_kernel(
    idx_ref, gate_ref, d_ref, x_ref, o_ref, acc_ref, *, bs: int, n_seg: int
):
    j = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[0, :]  # (k,)
    k = idx.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bs, k), 0) + j * bs
    P = (rows == idx[None, :]).astype(jnp.float32)  # -1 matches nothing
    gated = gate_ref[0][:, None] * d_ref[0].astype(jnp.float32)  # (k, D)
    acc_ref[...] += jax.lax.dot_general(
        P, gated, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(s == n_seg - 1)
    def _finish():
        o_ref[...] = x_ref[...] + acc_ref[...].astype(o_ref.dtype)


def ragged_scatter_add_rows(
    x: jax.Array,  # (T, D) flat stream
    idx: jax.Array,  # (n_seg, k) int32 flat indices, unique where >= 0
    delta: jax.Array,  # (n_seg, k, D)
    gate: jax.Array,  # (n_seg, k) f32 (0 at masked selections)
    *,
    interpret: bool = False,
    block_s: int = 256,
) -> jax.Array:  # (T, D)
    T, D = x.shape
    n_seg, k = idx.shape
    bs = _block_s(T, block_s)
    kernel = functools.partial(_ragged_scatter_kernel, bs=bs, n_seg=n_seg)
    return pl.pallas_call(
        kernel,
        grid=(T // bs, n_seg),
        in_specs=[
            pl.BlockSpec((1, k), lambda j, s: (s, 0)),
            pl.BlockSpec((1, k), lambda j, s: (s, 0)),
            pl.BlockSpec((1, k, D), lambda j, s: (s, 0, 0)),
            pl.BlockSpec((bs, D), lambda j, s: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bs, D), lambda j, s: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        scratch_shapes=[_vmem((bs, D), jnp.float32)],
        interpret=interpret,
    )(idx.astype(jnp.int32), gate.astype(jnp.float32), delta, x)


# ---------------------------------------------------------------------------
# Ragged paged write-back: W token rows into the pool in one pass
# ---------------------------------------------------------------------------


def ragged_page_targets(
    table: jax.Array,  # (B, P) int32
    slot: jax.Array,  # (W,) int32
    pos: jax.Array,  # (W,) int32 logical positions
    valid: jax.Array,  # (W,) bool
    page_size: int,
    dump_page: int,
) -> tuple:
    """(physical page id, in-page offset) per write row; invalid rows are
    routed to ``dump_page`` (the pool's scratch page) at offset 0."""
    P = table.shape[1]
    lpage = jnp.clip(pos // page_size, 0, P - 1)
    pid = table[jnp.clip(slot, 0, table.shape[0] - 1), lpage]
    pid = jnp.where(valid, pid, dump_page).astype(jnp.int32)
    off = jnp.where(valid, pos % page_size, 0).astype(jnp.int32)
    return pid, off


def ragged_paged_scatter_rows_xla(
    pages: jax.Array,  # lead + (N, p) + tail
    pid: jax.Array,  # (W,) physical page per row
    off: jax.Array,  # (W,) in-page offset per row
    rows: jax.Array,  # lead + (W,) + tail
    page_axis: int = 0,
) -> jax.Array:
    """pages[..., pid[w], off[w], ...] = rows[..., w, ...].

    Valid (pid, off) pairs are unique by contract (one write per token);
    dump-page rows may collide — their contents are garbage by contract.
    """
    N, p = pages.shape[page_axis], pages.shape[page_axis + 1]
    lead = pages.shape[:page_axis]
    tail = pages.shape[page_axis + 2 :]
    flat = pages.reshape(lead + (N * p,) + tail)
    fi = pid * p + off
    idx = (slice(None),) * len(lead) + (fi,)
    flat = flat.at[idx].set(rows.astype(flat.dtype))
    return flat.reshape(pages.shape)


def _ragged_ps_kernel(pid_ref, off_ref, rows_ref, page_ref, o_ref, *, n_rows: int):
    n = pl.program_id(0)
    o_ref[...] = page_ref[...]
    # every physical page checks each write row; W is the step's token
    # budget (small), so this is a short static loop
    for w in range(n_rows):
        @pl.when(pid_ref[w] == n)
        def _write(w=w):
            o_ref[0, pl.dslice(off_ref[w], 1), :] = rows_ref[pl.dslice(w, 1), :]


def ragged_paged_scatter_rows_pallas(
    pages: jax.Array,  # (N, p, F) canonical layout
    pid: jax.Array,  # (W,)
    off: jax.Array,  # (W,)
    rows: jax.Array,  # (W, F)
    *,
    interpret: bool = False,
) -> jax.Array:
    N, p, F = pages.shape
    W = pid.shape[0]
    kernel = functools.partial(_ragged_ps_kernel, n_rows=W)
    return pl.pallas_call(
        kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((W,), lambda n: (0,)),
            pl.BlockSpec((W,), lambda n: (0,)),
            pl.BlockSpec((W, F), lambda n: (0, 0)),
            pl.BlockSpec((1, p, F), lambda n: (n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, F), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, p, F), pages.dtype),
        interpret=interpret,
    )(pid.astype(jnp.int32), off.astype(jnp.int32), rows.astype(pages.dtype), pages)
