"""Pallas TPU flash attention (GQA + explicit position masking).

Tiling: grid = (B, nq, Nq, Nk); the last axis is "arbitrary" (sequential)
and accumulates the online softmax in VMEM scratch. Query/output blocks are
(block_q, head_dim); K/V blocks are (block_kv, head_dim), both sized so the
working set (q + k + v + scores + acc ≈ 2·bq·hd + 2·bkv·hd + bq·bkv floats)
fits comfortably in the ~16 MiB/core VMEM with MXU-aligned (multiple-of-128)
dims. GQA is expressed in the K/V index_map (query head h reads kv head
h·nkv/nq), so no K/V replication is materialized.

Masking is position-based: q_pos/kv_pos int32 arrays ride along in their own
blocks; causality is ``kv_pos <= q_pos`` on *original* token positions,
which makes the same kernel serve vanilla blocks (positions = arange) and
MoD routed blocks (sorted gathered positions). pos = -1 marks padding.

This module also holds :func:`routed_attention`, the attention half of the
``pallas_fused`` MoD backend: the routed-row gather rides the kernel
prologue as a one-hot selection matmul out of the full ``(B, S, D)``
residual stream (no standalone gather pass, no materialized sub-tensor),
and the kernel carries the whole pre-attention stage — RMSNorm, QKV
projection, RoPE — so the capacity-sized attention runs on rows that never
round-tripped through HBM. See DESIGN.md §Backend selection.

Current blocking: only the capacity axis is tiled (``block_k``); each grid
step stages the full ``(B, S, D)`` stream block and computes the dense
capacity-sized softmax — correct in interpret mode at any size, VMEM-bound
on real TPUs to roughly ``B·S·D ≲ 8M`` elements per core and re-reading
``x`` once per capacity tile. S/B-axis tiling (streaming the gather
accumulation like kernels/routing.py does) is the Mosaic follow-up; the
bit-for-bit contract vs the xla backend likewise assumes the xla block
takes the dense-``attend`` path (capacity ≤ 2048, which ``ratio·S`` keeps
true at the paper's settings).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512

# capacity-axis tile of the routed-attention kernel (module-level so tests
# can exercise the padding tail by shrinking it)
ROUTED_BLOCK_K = 128


def _flash_kernel(
    qpos_ref,
    kpos_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    n_kv_blocks: int,
):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
    v = v_ref[0, 0]  # (bkv, hd)
    qp = qpos_ref[0]  # (bq,)
    kp = kpos_ref[0]  # (bkv,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bkv)
    valid = (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window > 0:
        valid &= qp[:, None] - kp[None, :] < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, 0]  # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l_fin = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l_fin[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, nq, hd)
    k: jax.Array,  # (B, Skv, nkv, hd)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (B, Skv) int32
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, Sq, nq, hd)."""
    B, Sq, nq, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    assert nq % nkv == 0
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    bq, bkv = min(block_q, Sq), min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    Nq, Nk = Sq // bq, Skv // bkv

    # heads-first layout so blocks are contiguous (B, n, S, hd)
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)

    grid = (B, nq, Nq, Nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, n_kv_blocks=Nk
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bkv), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, _nkv=nkv, _nq=nq: (b, h * _nkv // _nq, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, _nkv=nkv, _nq=nq: (b, h * _nkv // _nq, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq, hd), jnp.float32),  # acc
            _vmem((bq, 1), jnp.float32),  # running max
            _vmem((bq, 1), jnp.float32),  # running denominator
        ],
        interpret=interpret,
    )(q_pos, kv_pos, qh, kh, vh)
    return jnp.swapaxes(out, 1, 2)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except (ImportError, AttributeError):  # pragma: no cover
        # jaxlib built without the TPU pallas extension (interpret-only
        # environments); anything else propagates — a real VMEM failure
        # must not silently demote the kernel's scratch space
        return pl.MemorySpace.ANY  # type: ignore


# ---------------------------------------------------------------------------
# Routed attention: MoD gather fused into the attention kernel prologue
# (the attention half of the "pallas_fused" backend, DESIGN.md §Backend
# selection). The kernel mirrors the xla block path op for op —
# models.layers.rmsnorm / apply_rope and models.attention._project_* /
# make_mask / attend — so its output is bit-for-bit equal to
# gather -> self_attention on the sub-tensor. Keep the mirrors in sync.
# ---------------------------------------------------------------------------


class RoutedAttnSpec(NamedTuple):
    """Static config of the routed-attention kernel (hashable: it rides
    custom_vjp's nondiff_argnums and jit static args)."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    scale: float
    causal: bool
    window: int
    rope_theta: float
    pos_emb: str  # "rope" | "none" (mrope falls back to the pallas backend)
    eps: float
    block_k: int
    interpret: bool


def _mirror_rmsnorm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    # mirrors models.layers.rmsnorm bitwise
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def _mirror_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    # mirrors models.layers.apply_rope bitwise (lax.iota, not jnp.arange:
    # pallas kernels may not capture array constants; 2i is exact in f32 so
    # the exponents are bit-identical)
    hd = x.shape[-1]
    exponents = jax.lax.iota(jnp.float32, hd // 2) * 2.0 / hd
    freqs = 1.0 / (theta**exponents)
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _attn_stage(
    hn_q: jax.Array,  # (B, rows, D) normed routed rows (q side)
    kv_rows_n: jax.Array,  # (B, k, D) normed KV-side rows (superset of q rows)
    qpos: jax.Array,  # (B, rows)
    kvpos: jax.Array,  # (B, k)
    params: Dict[str, jax.Array],
    spec: RoutedAttnSpec,
) -> jax.Array:
    """QKV -> RoPE -> masked attention -> out-proj on (pre-normed) routed
    rows. Shared between the kernel body and the VJP reference so both run
    the exact op sequence of the xla path (attention.self_attention); the
    caller norms ONCE and passes slices, matching the xla path's single
    rmsnorm -> {q,k,v} fan-out (a re-norm would re-associate the cotangent
    accumulation and break grad bit-equality)."""
    B, rows, _ = hn_q.shape
    k = kv_rows_n.shape[1]
    nq, nkv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = hn_q @ params["wq"]
    kk = kv_rows_n @ params["wk"]
    vv = kv_rows_n @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        kk = kk + params["bk"]
        vv = vv + params["bv"]
    q = q.reshape(B, rows, nq, hd)
    kk = kk.reshape(B, k, nkv, hd)
    vv = vv.reshape(B, k, nkv, hd)
    if spec.pos_emb == "rope":
        q = _mirror_rope(q, qpos, spec.rope_theta)
        kk = _mirror_rope(kk, jnp.maximum(kvpos, 0), spec.rope_theta)
    # mask mirrors models.attention.make_mask
    valid = kvpos[:, None, :] >= 0
    if spec.causal:
        valid = valid & (kvpos[:, None, :] <= qpos[:, :, None])
    if spec.window > 0:
        valid = valid & (qpos[:, :, None] - kvpos[:, None, :] < spec.window)
    # attention mirrors models.attention.attend
    g = nq // nkv
    qg = q.reshape(B, rows, nkv, g, hd)
    s = jnp.einsum("bsngh,btnh->bngst", qg, kk).astype(jnp.float32) * spec.scale
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bngst,btnh->bsngh", p, vv).reshape(B, rows, nq * hd)
    return o @ params["wo"]


def _onehot_gather(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Exact row selection as a one-hot f32 matmul (idx = -1 -> zero row)."""
    S = x.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (S,), idx.ndim)
    onehot = (idx[..., None] == cols).astype(jnp.float32)
    out = jnp.einsum("bks,bsd->bkd", onehot, x.astype(jnp.float32))
    return out.astype(x.dtype)


def _routed_attn_kernel(
    idx_ref, pos_ref, x_ref, ln_ref, wq_ref, wk_ref, wv_ref, wo_ref,
    *rest, spec: RoutedAttnSpec, k: int
):
    if len(rest) == 5:  # qkv_bias configs carry three extra operands
        bq_ref, bk_ref, bv_ref, a_ref, h_ref = rest
    else:
        (a_ref, h_ref), bq_ref, bk_ref, bv_ref = rest, None, None, None
    t = pl.program_id(0)
    bk = spec.block_k
    idx = idx_ref[...]  # (B, k_pad), pad entries are -1
    pos = pos_ref[...]  # (B, k_pad), pad entries are -1
    x = x_ref[...]  # (B, S, D)
    # gather folded into the prologue: routed rows come straight out of the
    # full residual stream; the sub-tensor never exists in HBM
    xs = _onehot_gather(x, idx)  # (B, k_pad, D)
    hn = _mirror_rmsnorm(ln_ref[...], xs, spec.eps)
    params = {
        "ln": ln_ref[...], "wq": wq_ref[...], "wk": wk_ref[...],
        "wv": wv_ref[...], "wo": wo_ref[...],
    }
    if bq_ref is not None:
        params.update(bq=bq_ref[...], bk=bk_ref[...], bv=bv_ref[...])
    # KV stays the routed capacity-sized set: slice *statically* to the true
    # capacity k so softmax reductions see exactly the xla path's axis
    # length (padding an f32 reduction reorders it — measured non-bitwise)
    xs_t = jax.lax.dynamic_slice_in_dim(xs, t * bk, bk, axis=1)
    hn_t = jax.lax.dynamic_slice_in_dim(hn, t * bk, bk, axis=1)
    qpos_t = jax.lax.dynamic_slice_in_dim(pos, t * bk, bk, axis=1)
    a = _attn_stage(hn_t, hn[:, :k], qpos_t, pos[:, :k], params, spec)
    a_ref[...] = a
    h_ref[...] = xs_t + a


def _routed_attention_call(x, idx, pos_sub, params, spec: RoutedAttnSpec):
    B, S, D = x.shape
    k = idx.shape[1]
    bk = min(spec.block_k, k)
    spec = spec._replace(block_k=bk)
    k_pad = -(-k // bk) * bk
    if k_pad != k:
        pad = ((0, 0), (0, k_pad - k))
        idx = jnp.pad(idx, pad, constant_values=-1)
        pos_sub = jnp.pad(pos_sub, pad, constant_values=-1)
    has_bias = "bq" in params
    args = [idx, pos_sub, x, params["ln"], params["wq"], params["wk"],
            params["wv"], params["wo"]]
    in_specs = [
        pl.BlockSpec((B, k_pad), lambda t: (0, 0)),
        pl.BlockSpec((B, k_pad), lambda t: (0, 0)),
        pl.BlockSpec((B, S, D), lambda t: (0, 0, 0)),
        pl.BlockSpec(params["ln"].shape, lambda t: (0,)),
        pl.BlockSpec(params["wq"].shape, lambda t: (0, 0)),
        pl.BlockSpec(params["wk"].shape, lambda t: (0, 0)),
        pl.BlockSpec(params["wv"].shape, lambda t: (0, 0)),
        pl.BlockSpec(params["wo"].shape, lambda t: (0, 0)),
    ]
    if has_bias:
        for key in ("bq", "bk", "bv"):
            args.append(params[key])
            in_specs.append(pl.BlockSpec(params[key].shape, lambda t: (0,)))
    kernel_fn = functools.partial(_routed_attn_kernel, spec=spec, k=k)
    a, h = pl.pallas_call(
        kernel_fn,
        grid=(k_pad // bk,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((B, bk, D), lambda t: (0, t, 0)),
            pl.BlockSpec((B, bk, D), lambda t: (0, t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k_pad, D), x.dtype),
            jax.ShapeDtypeStruct((B, k_pad, D), x.dtype),
        ],
        interpret=spec.interpret,
    )(*args)
    return a[:, :k], h[:, :k]


def _routed_attention_host(x, idx, pos_sub, params, spec: RoutedAttnSpec):
    """Pure-jnp mirror of the kernel == the xla backend composition
    (take_along_axis gather -> rmsnorm -> self_attention). The custom VJP
    differentiates *this*, so fused grads are the xla path's grads."""
    x_sub = jnp.take_along_axis(x, idx[..., None], axis=1)
    hn = _mirror_rmsnorm(params["ln"], x_sub, spec.eps)
    a = _attn_stage(hn, hn, pos_sub, pos_sub, params, spec)
    return a, x_sub + a


def _float0(a):
    return np.zeros(a.shape, jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _routed_attention(x, idx, pos_sub, params, spec):
    return _routed_attention_call(x, idx, pos_sub, params, spec)


def _routed_attention_fwd(x, idx, pos_sub, params, spec):
    return _routed_attention_call(x, idx, pos_sub, params, spec), (
        x, idx, pos_sub, params,
    )


def _routed_attention_bwd(spec, res, g):
    x, idx, pos_sub, params = res
    _, vjp = jax.vjp(
        lambda x_, p_: _routed_attention_host(x_, idx, pos_sub, p_, spec), x, params
    )
    dx, dparams = vjp(g)
    return dx, _float0(idx), _float0(pos_sub), dparams


_routed_attention.defvjp(_routed_attention_fwd, _routed_attention_bwd)


def routed_attention(
    x: jax.Array,  # (B, S, D) full residual stream
    idx: jax.Array,  # (B, k) int32 routed rows, sorted unique
    pos_sub: jax.Array,  # (B, k) int32 original positions of routed rows
    params: Dict[str, jax.Array],  # ln, wq, wk, wv, wo (+ bq, bk, bv)
    spec: RoutedAttnSpec,
) -> Tuple[jax.Array, jax.Array]:
    """Fused-dispatch routed attention.

    Returns ``(a_sub, h_sub)``: the attention residual contribution on the
    routed rows and the post-attention hidden ``x[idx] + a`` that feeds the
    routed-MLP kernel — both (B, k, D); no (B, k, D) gather of ``x`` is ever
    written to HBM on the forward path.
    """
    return _routed_attention(x, idx, pos_sub, params, spec)
