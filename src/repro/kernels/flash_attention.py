"""Pallas TPU flash attention (GQA + explicit position masking).

Tiling: grid = (B, nq, Nq, Nk); the last axis is "arbitrary" (sequential)
and accumulates the online softmax in VMEM scratch. Query/output blocks are
(block_q, head_dim); K/V blocks are (block_kv, head_dim), both sized so the
working set (q + k + v + scores + acc ≈ 2·bq·hd + 2·bkv·hd + bq·bkv floats)
fits comfortably in the ~16 MiB/core VMEM with MXU-aligned (multiple-of-128)
dims. GQA is expressed in the K/V index_map (query head h reads kv head
h·nkv/nq), so no K/V replication is materialized.

Masking is position-based: q_pos/kv_pos int32 arrays ride along in their own
blocks; causality is ``kv_pos <= q_pos`` on *original* token positions,
which makes the same kernel serve vanilla blocks (positions = arange) and
MoD routed blocks (sorted gathered positions). pos = -1 marks padding.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512


def _flash_kernel(
    qpos_ref,
    kpos_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    n_kv_blocks: int,
):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bkv, hd)
    v = v_ref[0, 0]  # (bkv, hd)
    qp = qpos_ref[0]  # (bq,)
    kp = kpos_ref[0]  # (bkv,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bkv)
    valid = (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window > 0:
        valid &= qp[:, None] - kp[None, :] < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, 0]  # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l_fin = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l_fin[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, nq, hd)
    k: jax.Array,  # (B, Skv, nkv, hd)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (B, Skv) int32
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, Sq, nq, hd)."""
    B, Sq, nq, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    assert nq % nkv == 0
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    bq, bkv = min(block_q, Sq), min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    Nq, Nk = Sq // bq, Skv // bkv

    # heads-first layout so blocks are contiguous (B, n, S, hd)
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)

    grid = (B, nq, Nq, Nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, n_kv_blocks=Nk
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bkv), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, _nkv=nkv, _nq=nq: (b, h * _nkv // _nq, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, _nkv=nkv, _nq=nq: (b, h * _nkv // _nq, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq, hd), jnp.float32),  # acc
            _vmem((bq, 1), jnp.float32),  # running max
            _vmem((bq, 1), jnp.float32),  # running denominator
        ],
        interpret=interpret,
    )(q_pos, kv_pos, qh, kh, vh)
    return jnp.swapaxes(out, 1, 2)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - interpret-only environments
        return pl.MemorySpace.ANY  # type: ignore
