"""Pallas kernels for the block-paged KV pool (serve/cache.PagedCachePool).

A paged cache leaf stores its per-position axis as ``(n_pages, page_size)``
physical blocks instead of a contiguous ``(B, ctx)`` slab; a per-slot page
table ``(B, P = ctx // page_size)`` maps logical pages to physical ones.
Two decode-only data-movement ops (no VJP — the serving step never
differentiates):

- ``paged_gather(pages, table)``: materialize every slot's logical
  ``(ctx,)`` view for the attention read —
  ``out[b, i*p + r] = pages[table[b, i], r]``. The page table rides the
  grid as a scalar-prefetch operand so each (b, i) grid step DMAs exactly
  one physical page (the vLLM paged-attention read pattern).
- ``paged_scatter_rows(pages, table, rows, pos)``: write the decode step's
  single new row per slot into its tail page —
  ``pages[table[b, pos[b] // p], pos[b] % p] = rows[b]``. The grid walks
  physical pages, so untouched pages stream through unchanged and the op
  needs no input/output aliasing to be total.

Both run in ``interpret=True`` on CPU (validated against ``kernels/ref.py``
oracles in tests/test_paged.py) and lower to Mosaic on TPU. The canonical
layout is ``pages (N, p, F)`` / ``rows (B, F)``; the leaf-shaped wrappers
in ``kernels/ops.py`` fold arbitrary lead/tail dims into F.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# XLA reference implementations (the serving engine's default backend)
# ---------------------------------------------------------------------------


def paged_gather_xla(pages: jax.Array, table: jax.Array, page_axis: int = 0) -> jax.Array:
    """out[..., b, i*p + r, ...] = pages[..., table[b, i], r, ...].

    ``pages``: lead + (N, p) + tail with the page axis at ``page_axis``;
    ``table``: (B, P) int32. Returns lead + (B, P*p) + tail.
    """
    p = pages.shape[page_axis + 1]
    B, P = table.shape
    out = jnp.take(pages, table, axis=page_axis)  # lead + (B, P, p) + tail
    shape = pages.shape[:page_axis] + (B, P * p) + pages.shape[page_axis + 2 :]
    return out.reshape(shape)


def paged_scatter_rows_xla(
    pages: jax.Array,  # lead + (N, p) + tail
    table: jax.Array,  # (B, P) int32
    rows: jax.Array,  # lead + (B,) + tail — one new row per slot
    pos: jax.Array,  # (B,) int32 logical positions
    page_axis: int = 0,
) -> jax.Array:
    """pages[..., table[b, pos[b]//p], pos[b]%p, ...] = rows[..., b, ...].

    Slots whose page-table entry routes to a reserved scratch page may
    collide; writes there are garbage by contract (free slots).
    """
    N, p = pages.shape[page_axis], pages.shape[page_axis + 1]
    lead = pages.shape[:page_axis]
    tail = pages.shape[page_axis + 2 :]
    flat = pages.reshape(lead + (N * p,) + tail)
    pid = jnp.take_along_axis(table, (pos // p)[:, None], axis=1)[:, 0]  # (B,)
    fi = pid * p + pos % p
    idx = (slice(None),) * len(lead) + (fi,)
    flat = flat.at[idx].set(rows.astype(flat.dtype))
    return flat.reshape(pages.shape)


# ---------------------------------------------------------------------------
# Pallas variants (canonical (N, p, F) layout)
# ---------------------------------------------------------------------------


def _gather_kernel(tbl_ref, page_ref, o_ref):
    # the BlockSpec index_map already selected page table[b, i]; pure copy
    o_ref[0, 0] = page_ref[0]


def paged_gather_pallas(
    pages: jax.Array,  # (N, p, F)
    table: jax.Array,  # (B, P) int32
    *,
    interpret: bool = False,
) -> jax.Array:  # (B, P*p, F)
    N, p, F = pages.shape
    B, P = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[pl.BlockSpec((1, p, F), lambda b, i, tbl: (tbl[b, i], 0, 0))],
        out_specs=pl.BlockSpec((1, 1, p, F), lambda b, i, tbl: (b, i, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, p, F), pages.dtype),
        interpret=interpret,
    )(table, pages)
    return out.reshape(B, P * p, F)


def _gather_dequant_kernel(tbl_ref, page_ref, scale_ref, o_ref):
    # fused dequant: the narrow page is widened in VMEM right after the DMA
    # — quantized KV never crosses HBM at full width. The block multiply is
    # the same expression the xla reference uses (serve/quant.dequant_rows),
    # so both backends produce identical bits.
    from repro.serve.quant import dequant_rows

    o_ref[0, 0] = dequant_rows(page_ref[0], scale_ref[0])


def paged_gather_dequant_pallas(
    pages: jax.Array,  # (N, p, F) narrow (int8 | fp8)
    scales: jax.Array,  # (N, p, G) f32 per-row(-block) scales
    table: jax.Array,  # (B, P) int32
    *,
    interpret: bool = False,
) -> jax.Array:  # (B, P*p, F) f32
    N, p, F = pages.shape
    G = scales.shape[-1]
    B, P = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, p, F), lambda b, i, tbl: (tbl[b, i], 0, 0)),
            pl.BlockSpec((1, p, G), lambda b, i, tbl: (tbl[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, p, F), lambda b, i, tbl: (b, i, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P, p, F), jnp.float32),
        interpret=interpret,
    )(table, pages, scales)
    return out.reshape(B, P * p, F)


def paged_gather_dequant_xla(
    pages: jax.Array,  # (N, p, F) narrow
    scales: jax.Array,  # (N, p, G) f32
    table: jax.Array,  # (B, P) int32
) -> jax.Array:  # (B, P*p, F) f32
    """XLA reference of the fused-dequant gather: gather narrow pages and
    their scales, widen with the shared block multiply."""
    from repro.serve.quant import dequant_rows

    return dequant_rows(
        paged_gather_xla(pages, table), paged_gather_xla(scales, table)
    )


def _scatter_kernel(pid_ref, off_ref, rows_ref, page_ref, o_ref, *, n_slots: int):
    n = pl.program_id(0)
    o_ref[...] = page_ref[...]
    # each physical page checks every slot for a write landing on it; B is
    # the decode batch (small), so this is a short static loop
    for b in range(n_slots):
        @pl.when(pid_ref[b] == n)
        def _write(b=b):
            o_ref[0, pl.dslice(off_ref[b], 1), :] = rows_ref[pl.dslice(b, 1), :]


def paged_scatter_rows_pallas(
    pages: jax.Array,  # (N, p, F)
    table: jax.Array,  # (B, P) int32
    rows: jax.Array,  # (B, F)
    pos: jax.Array,  # (B,) int32
    *,
    interpret: bool = False,
) -> jax.Array:  # (N, p, F)
    N, p, F = pages.shape
    B = pos.shape[0]
    pid = jnp.take_along_axis(table, (pos // p)[:, None], axis=1)[:, 0]
    off = (pos % p).astype(jnp.int32)
    kernel = functools.partial(_scatter_kernel, n_slots=B)
    return pl.pallas_call(
        kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((B,), lambda n: (0,)),
            pl.BlockSpec((B,), lambda n: (0,)),
            pl.BlockSpec((B, F), lambda n: (0, 0)),
            pl.BlockSpec((1, p, F), lambda n: (n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, F), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, p, F), pages.dtype),
        interpret=interpret,
    )(pid.astype(jnp.int32), off, rows.astype(pages.dtype), pages)
