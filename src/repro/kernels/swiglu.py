"""Pallas TPU fused SwiGLU MLP: y = (silu(x Wg) * (x Wu)) Wd in one pass.

Grid = (M/bm, F/bf); the F axis is sequential ("arbitrary") and accumulates
the down-projection into a VMEM f32 scratch, so the (M, F) hidden
activation is never materialized in HBM — the fusion that matters for the
memory-roofline term of the MLP. Block sizes default to bm=256, bf=512:
VMEM footprint = x (bm, D) + Wg/Wu (D, bf) + Wd (bf, D) + acc (bm, D)
≈ 2·bm·D·2 + 3·D·bf·2 + bm·D·4 bytes ≈ 13 MiB at D=4096 — inside the
16 MiB/core budget, all dims 128-aligned for the MXU.

This module also holds :func:`routed_mlp_scatter`, the MLP half of the
``pallas_fused`` MoD backend: the block's (Swi/Ge)GLU MLP runs on the
capacity-sized routed rows and the kernel epilogue performs the gated
scatter-add ``x + P @ (gate·(a + m))`` of paper Eq. 1 in the same pass —
the standalone scatter pass of the xla/pallas backends disappears. See
DESIGN.md §Backend selection.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the bitwise models.layers mirrors + float0 helper are shared with the
# routed-attention kernel so the two fused halves can never drift apart
from repro.kernels.flash_attention import _float0, _mirror_rmsnorm


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, D)
    g = jax.lax.dot_general(
        x, wg_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    u = jax.lax.dot_general(
        x, wu_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = (jax.nn.silu(g) * u).astype(x.dtype)  # (bm, bf)
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == n_f_blocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def swiglu(
    x: jax.Array,  # (M, D)
    w_gate: jax.Array,  # (D, F)
    w_up: jax.Array,  # (D, F)
    w_down: jax.Array,  # (F, D)
    *,
    block_m: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, D = x.shape
    F = w_gate.shape[1]
    bm, bf = min(block_m, M), min(block_f, F)
    assert M % bm == 0 and F % bf == 0, (M, bm, F, bf)
    grid = (M // bm, F // bf)
    kernel = functools.partial(_swiglu_kernel, n_f_blocks=F // bf)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        scratch_shapes=[_vmem((bm, D), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except (ImportError, AttributeError):  # pragma: no cover
        # jaxlib built without the TPU pallas extension (interpret-only
        # environments); anything else propagates — a real VMEM failure
        # must not silently demote the kernel's scratch space
        return pl.MemorySpace.ANY  # type: ignore


# ---------------------------------------------------------------------------
# Routed MLP with gated scatter-add epilogue (the MLP half of the
# "pallas_fused" backend). The MLP math mirrors models.layers.mlp and the
# epilogue mirrors core.routing._scatter_add_tokens bitwise; the custom VJP
# differentiates the mirror, so grads equal the xla path's.
# ---------------------------------------------------------------------------


class RoutedMlpSpec(NamedTuple):
    """Static config (hashable for nondiff_argnums / jit static args)."""

    act: str  # "silu" | "gelu"
    eps: float
    block_s: int
    interpret: bool


def _mirror_mlp(params: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    # mirrors models.layers.mlp bitwise
    act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act_fn(x @ params["w_gate"]) * up
    else:
        up = act_fn(up)
    return up @ params["w_down"]


def _gated_delta(params, h_sub, a_sub, gate, spec: RoutedMlpSpec) -> jax.Array:
    """f32 gated block delta gate·(a + mlp(norm(h))) — shared by kernel/ref."""
    hn = _mirror_rmsnorm(params["ln"], h_sub, spec.eps)
    m = _mirror_mlp(params, hn, spec.act)
    delta = a_sub + m
    return gate[..., None] * delta.astype(jnp.float32)


def _routed_mlp_kernel(
    idx_ref, gate_ref, h_ref, a_ref, ln_ref, wu_ref, wd_ref,
    *rest, spec: RoutedMlpSpec, bs: int
):
    if len(rest) == 4:  # GLU configs carry the gate projection
        wg_ref, x_ref, o_ref, acc_ref = rest
    else:
        (x_ref, o_ref, acc_ref), wg_ref = rest, None
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _mlp():
        # the capacity-sized MLP runs once; its gated delta lives in VMEM
        # scratch for the scatter epilogue below
        params = {"ln": ln_ref[...], "w_up": wu_ref[...], "w_down": wd_ref[...]}
        if wg_ref is not None:
            params["w_gate"] = wg_ref[...]
        acc_ref[...] = _gated_delta(params, h_ref[...], a_ref[...], gate_ref[...], spec)

    # epilogue: gated scatter-add of the delta into this output S-block
    # (one-hot matmul; unique idx -> each row gets at most one contribution,
    # bit-exact vs at[].add — same formulation as kernels/routing.py)
    idx = idx_ref[...]  # (B, k)
    B, k = idx.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (B, bs, k), 1) + j * bs
    P = (rows == idx[:, None, :]).astype(jnp.float32)
    upd = jnp.einsum("bsk,bkd->bsd", P, acc_ref[...])
    o_ref[...] = x_ref[...] + upd.astype(o_ref.dtype)


def _block_div(seq_len: int, block_s: int) -> int:
    bs = min(block_s, seq_len)
    while seq_len % bs:
        bs -= 1
    return bs


def _routed_mlp_call(x, h_sub, a_sub, idx, gate, params, spec: RoutedMlpSpec):
    B, S, D = x.shape
    k = idx.shape[1]
    F = params["w_up"].shape[1]
    bs = _block_div(S, spec.block_s)
    args = [idx, gate.astype(jnp.float32), h_sub, a_sub,
            params["ln"], params["w_up"], params["w_down"]]
    in_specs = [
        pl.BlockSpec((B, k), lambda j: (0, 0)),
        pl.BlockSpec((B, k), lambda j: (0, 0)),
        pl.BlockSpec((B, k, D), lambda j: (0, 0, 0)),
        pl.BlockSpec((B, k, D), lambda j: (0, 0, 0)),
        pl.BlockSpec(params["ln"].shape, lambda j: (0,)),
        pl.BlockSpec((D, F), lambda j: (0, 0)),
        pl.BlockSpec((F, D), lambda j: (0, 0)),
    ]
    if "w_gate" in params:
        args.append(params["w_gate"])
        in_specs.append(pl.BlockSpec((D, F), lambda j: (0, 0)))
    args.append(x)
    in_specs.append(pl.BlockSpec((B, bs, D), lambda j: (0, j, 0)))
    kernel_fn = functools.partial(_routed_mlp_kernel, spec=spec, bs=bs)
    return pl.pallas_call(
        kernel_fn,
        grid=(S // bs,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((B, bs, D), lambda j: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        scratch_shapes=[_vmem((B, k, D), jnp.float32)],
        interpret=spec.interpret,
    )(*args)


def _routed_mlp_host(x, h_sub, a_sub, idx, gate, params, spec: RoutedMlpSpec):
    """Pure-jnp mirror == the xla composition (rmsnorm -> mlp -> gated
    at[].add). The custom VJP differentiates this."""
    gated = _gated_delta(params, h_sub, a_sub, gate, spec)
    update = gated.astype(x.dtype)
    B = x.shape[0]
    return x.at[jnp.arange(B)[:, None], idx].add(update)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _routed_mlp_scatter(x, h_sub, a_sub, idx, gate, params, spec):
    return _routed_mlp_call(x, h_sub, a_sub, idx, gate, params, spec)


def _routed_mlp_fwd(x, h_sub, a_sub, idx, gate, params, spec):
    out = _routed_mlp_call(x, h_sub, a_sub, idx, gate, params, spec)
    return out, (x, h_sub, a_sub, idx, gate, params)


def _routed_mlp_bwd(spec, res, g):
    x, h_sub, a_sub, idx, gate, params = res
    _, vjp = jax.vjp(
        lambda x_, h_, a_, g_, p_: _routed_mlp_host(x_, h_, a_, idx, g_, p_, spec),
        x, h_sub, a_sub, gate, params,
    )
    dx, dh, da, dgate, dparams = vjp(g)
    return dx, dh, da, _float0(idx), dgate, dparams


_routed_mlp_scatter.defvjp(_routed_mlp_fwd, _routed_mlp_bwd)


def routed_mlp_scatter(
    x: jax.Array,  # (B, S, D) full residual stream
    h_sub: jax.Array,  # (B, k, D) post-attention hidden of routed rows
    a_sub: jax.Array,  # (B, k, D) attention contribution of routed rows
    idx: jax.Array,  # (B, k) int32 routed rows, sorted unique
    gate: jax.Array,  # (B, k) f32 router gates
    params: Dict[str, jax.Array],  # ln, w_up, w_down (+ w_gate)
    spec: RoutedMlpSpec,
) -> jax.Array:  # (B, S, D)
    """Routed-MLP kernel whose epilogue is paper Eq. 1's gated combine:
    ``out = x + P @ (gate · (a + mlp(rmsnorm(h))))`` in a single pass over
    the residual stream — no standalone scatter kernel, no HBM round trip
    for the block delta."""
    return _routed_mlp_scatter(x, h_sub, a_sub, idx, gate, params, spec)
