"""Pallas TPU fused SwiGLU MLP: y = (silu(x Wg) * (x Wu)) Wd in one pass.

Grid = (M/bm, F/bf); the F axis is sequential ("arbitrary") and accumulates
the down-projection into a VMEM f32 scratch, so the (M, F) hidden
activation is never materialized in HBM — the fusion that matters for the
memory-roofline term of the MLP. Block sizes default to bm=256, bf=512:
VMEM footprint = x (bm, D) + Wg/Wu (D, bf) + Wd (bf, D) + acc (bm, D)
≈ 2·bm·D·2 + 3·D·bf·2 + bm·D·4 bytes ≈ 13 MiB at D=4096 — inside the
16 MiB/core budget, all dims 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, D)
    g = jax.lax.dot_general(
        x, wg_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    u = jax.lax.dot_general(
        x, wu_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = (jax.nn.silu(g) * u).astype(x.dtype)  # (bm, bf)
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == n_f_blocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def swiglu(
    x: jax.Array,  # (M, D)
    w_gate: jax.Array,  # (D, F)
    w_up: jax.Array,  # (D, F)
    w_down: jax.Array,  # (F, D)
    *,
    block_m: int = 256,
    block_f: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, D = x.shape
    F = w_gate.shape[1]
    bm, bf = min(block_m, M), min(block_f, F)
    assert M % bm == 0 and F % bf == 0, (M, bm, F, bf)
    grid = (M // bm, F // bf)
    kernel = functools.partial(_swiglu_kernel, n_f_blocks=F // bf)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        scratch_shapes=[_vmem((bm, D), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY  # type: ignore
