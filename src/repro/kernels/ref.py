"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Deliberately written as the most direct O(n^2)/O(n*d) formulations —
independent of the blocked/online implementations they validate.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, Sq, nq, hd)
    k: jax.Array,  # (B, Skv, nkv, hd)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    kr = jnp.repeat(k, g, axis=2)  # (B, Skv, nq, hd)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    valid = (kv_pos[:, None, :] >= 0) & (q_pos[:, :, None] >= 0)
    if causal:
        valid &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        valid &= q_pos[:, :, None] - kv_pos[:, None, :] < window
    s = jnp.where(valid[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with nothing valid -> zero output (matches online-softmax guard)
    any_valid = jnp.any(valid, axis=-1)[:, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr)
    return out.astype(q.dtype)


def ssd_chunk_ref(
    x: jax.Array,  # (Q, hd) one chunk, one head
    loglam: jax.Array,  # (Q,) = dt * A  (<= 0)
    dt: jax.Array,  # (Q,)
    Bm: jax.Array,  # (Q, ds)
    Cm: jax.Array,  # (Q, ds)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential-recurrence oracle for one SSD chunk.

    Returns (y_intra (Q, hd), state_increment (hd, ds)); the recurrence
    starts from a zero state so y here is the *intra-chunk* contribution.
    """
    Q, hd = x.shape
    ds = Bm.shape[-1]
    s = jnp.zeros((hd, ds), jnp.float32)
    ys = []
    for t in range(Q):
        lam = jnp.exp(loglam[t])
        s = lam * s + dt[t] * jnp.outer(x[t].astype(jnp.float32), Bm[t].astype(jnp.float32))
        ys.append(s @ Cm[t].astype(jnp.float32))
    return jnp.stack(ys), s


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gather_rows_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    """MoD dispatch oracle: out[b, i] = x[b, idx[b, i]] via a dense one-hot
    contraction (independent of both the XLA take_along_axis backend and the
    blocked pallas kernel)."""
    B, S, _ = x.shape
    onehot = (idx[..., None] == jnp.arange(S)[None, None, :]).astype(jnp.float32)
    out = jnp.einsum("bks,bsd->bkd", onehot, x.astype(jnp.float32))
    return out.astype(x.dtype)


def scatter_add_rows_ref(
    x: jax.Array,  # (B, S, D)
    idx: jax.Array,  # (B, k) unique per row
    delta: jax.Array,  # (B, k, D)
    gate: jax.Array,  # (B, k) f32
) -> jax.Array:
    """MoD combine oracle: out[b, s] = x[b, s] + cast(gate * delta) for the
    (at most one, since top-k indices are unique) i with idx[b, i] == s."""
    B, S, _ = x.shape
    onehot = (idx[..., None] == jnp.arange(S)[None, None, :]).astype(jnp.float32)
    gated = gate[..., None].astype(jnp.float32) * delta.astype(jnp.float32)
    upd = jnp.einsum("bks,bkd->bsd", onehot, gated)
    return x + upd.astype(x.dtype)
