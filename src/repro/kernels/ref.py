"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Deliberately written as the most direct O(n^2)/O(n*d) formulations —
independent of the blocked/online implementations they validate.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, Sq, nq, hd)
    k: jax.Array,  # (B, Skv, nkv, hd)
    v: jax.Array,
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else 1.0 / (hd**0.5)
    kr = jnp.repeat(k, g, axis=2)  # (B, Skv, nq, hd)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    valid = (kv_pos[:, None, :] >= 0) & (q_pos[:, :, None] >= 0)
    if causal:
        valid &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        valid &= q_pos[:, :, None] - kv_pos[:, None, :] < window
    s = jnp.where(valid[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with nothing valid -> zero output (matches online-softmax guard)
    any_valid = jnp.any(valid, axis=-1)[:, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr)
    return out.astype(q.dtype)


def ssd_chunk_ref(
    x: jax.Array,  # (Q, hd) one chunk, one head
    loglam: jax.Array,  # (Q,) = dt * A  (<= 0)
    dt: jax.Array,  # (Q,)
    Bm: jax.Array,  # (Q, ds)
    Cm: jax.Array,  # (Q, ds)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential-recurrence oracle for one SSD chunk.

    Returns (y_intra (Q, hd), state_increment (hd, ds)); the recurrence
    starts from a zero state so y here is the *intra-chunk* contribution.
    """
    Q, hd = x.shape
    ds = Bm.shape[-1]
    s = jnp.zeros((hd, ds), jnp.float32)
    ys = []
    for t in range(Q):
        lam = jnp.exp(loglam[t])
        s = lam * s + dt[t] * jnp.outer(x[t].astype(jnp.float32), Bm[t].astype(jnp.float32))
        ys.append(s @ Cm[t].astype(jnp.float32))
    return jnp.stack(ys), s


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gather_rows_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    """MoD dispatch oracle: out[b, i] = x[b, idx[b, i]] via a dense one-hot
    contraction (independent of both the XLA take_along_axis backend and the
    blocked pallas kernel)."""
    B, S, _ = x.shape
    onehot = (idx[..., None] == jnp.arange(S)[None, None, :]).astype(jnp.float32)
    out = jnp.einsum("bks,bsd->bkd", onehot, x.astype(jnp.float32))
    return out.astype(x.dtype)


def scatter_add_rows_ref(
    x: jax.Array,  # (B, S, D)
    idx: jax.Array,  # (B, k) unique per row
    delta: jax.Array,  # (B, k, D)
    gate: jax.Array,  # (B, k) f32
) -> jax.Array:
    """MoD combine oracle: out[b, s] = x[b, s] + cast(gate * delta) for the
    (at most one, since top-k indices are unique) i with idx[b, i] == s."""
    B, S, _ = x.shape
    onehot = (idx[..., None] == jnp.arange(S)[None, None, :]).astype(jnp.float32)
    gated = gate[..., None].astype(jnp.float32) * delta.astype(jnp.float32)
    upd = jnp.einsum("bks,bkd->bsd", onehot, gated)
    return x + upd.astype(x.dtype)


# ---------------------------------------------------------------------------
# Paged KV-pool oracles (serve/cache.PagedCachePool; canonical (N, p, F)
# layout): literal per-slot loops, independent of both the XLA take/at-set
# formulation and the pallas grid kernels.
# ---------------------------------------------------------------------------


def paged_gather_ref(pages: jax.Array, table: jax.Array) -> jax.Array:
    """out[b, i*p + r] = pages[table[b, i], r]. pages (N,p,F), table (B,P)."""
    import numpy as np

    pages_np, table_np = np.asarray(pages), np.asarray(table)
    _, p, F = pages_np.shape
    B, P = table_np.shape
    out = np.zeros((B, P * p, F), pages_np.dtype)
    for b in range(B):
        for i in range(P):
            out[b, i * p : (i + 1) * p] = pages_np[table_np[b, i]]
    return jnp.asarray(out)


def paged_scatter_rows_ref(
    pages: jax.Array,  # (N, p, F)
    table: jax.Array,  # (B, P)
    rows: jax.Array,  # (B, F)
    pos: jax.Array,  # (B,) logical positions
) -> jax.Array:
    """pages[table[b, pos[b]//p], pos[b]%p] = rows[b], slot by slot."""
    import numpy as np

    pages_np = np.asarray(pages).copy()
    table_np, rows_np, pos_np = np.asarray(table), np.asarray(rows), np.asarray(pos)
    p = pages_np.shape[1]
    for b in range(pos_np.shape[0]):
        pages_np[table_np[b, pos_np[b] // p], pos_np[b] % p] = rows_np[b]
    return jnp.asarray(pages_np)


# ---------------------------------------------------------------------------
# Quantized-KV oracles (serve/quant.py + the fused-dequant kernels):
# numpy re-derivations of the pow2 scale scheme and the widen-on-gather
# path, independent of the jnp/bitcast formulation they validate.
# ---------------------------------------------------------------------------


def pow2_scale_ref(absmax, qmax: float):
    """Smallest normal power of two >= absmax/qmax (numpy mirror of
    serve/quant.pow2_scale's exponent-field arithmetic)."""
    import numpy as np

    r = np.atleast_1d(np.asarray(absmax, np.float32) / np.float32(qmax))
    bits = r.view(np.uint32)
    exp = ((bits >> 23) & 0xFF).astype(np.int32) - 127
    frac = (bits & 0x7FFFFF) != 0
    e = np.clip(exp + frac.astype(np.int32), -126, 127)
    s = (((e + 127).astype(np.uint32)) << 23).view(np.float32)
    s = np.where(r > 0, s, np.float32(1.0))
    return s.reshape(np.shape(absmax))


def quantize_rows_ref(x, n_groups: int, kind: str):
    """(q, scales) for canonical rows (..., F) with per-block pow2 scales."""
    import numpy as np

    qmax = 127.0 if kind == "int8" else 448.0
    xf = np.asarray(x, np.float32)
    xb = xf.reshape(xf.shape[:-1] + (n_groups, -1))
    s = pow2_scale_ref(np.max(np.abs(xb), axis=-1), qmax)
    y = xb / s[..., None]
    if kind == "int8":
        q = np.clip(np.rint(y), -qmax, qmax).astype(np.int8).reshape(xf.shape)
        return jnp.asarray(q), jnp.asarray(s)
    q = jnp.asarray(np.clip(y, -qmax, qmax).reshape(xf.shape))
    return q.astype(jnp.float8_e4m3fn), jnp.asarray(s)


def dequantize_rows_ref(q, scales):
    """Widen canonical rows (..., F) narrow + (..., G) scales -> f32."""
    import numpy as np

    qf = np.asarray(jnp.asarray(q).astype(jnp.float32))
    s = np.asarray(scales, np.float32)
    yb = qf.reshape(qf.shape[:-1] + (s.shape[-1], -1)) * s[..., None]
    return jnp.asarray(yb.reshape(qf.shape))


def paged_gather_dequant_ref(pages: jax.Array, scales: jax.Array,
                             table: jax.Array) -> jax.Array:
    """Fused-dequant gather oracle: gather narrow pages (N,p,F) and their
    scales (N,p,G) page by page, then widen block-wise."""
    return dequantize_rows_ref(
        paged_gather_ref(pages, table), paged_gather_ref(scales, table)
    )


def ragged_attention_quant_ref(
    q: jax.Array,  # (T, nq, hd) flat query stream
    k_pages: jax.Array,  # (N, p, nkv, hd) narrow
    k_scales: jax.Array,  # (N, p, nkv) f32
    v_pages: jax.Array,
    v_scales: jax.Array,
    pos_pages: jax.Array,
    table: jax.Array,
    row_offsets: jax.Array,
    seg_slot: jax.Array,
    q_pos: jax.Array,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Quantized ragged-attention oracle: widen every KV page with its
    per-(page, row, kv-head) scale, then delegate to the fp32 oracle."""
    kf = k_pages.astype(jnp.float32) * k_scales[..., None]
    vf = v_pages.astype(jnp.float32) * v_scales[..., None]
    return ragged_attention_ref(
        q, kf, vf, pos_pages, table, row_offsets, seg_slot, q_pos,
        causal=causal, window=window, scale=scale,
    )


# ---------------------------------------------------------------------------
# Ragged flat-token oracles (kernels/ragged.py): literal per-segment /
# per-row loops over the flat stream, independent of the blocked kernels
# and of the one-hot / scalar-prefetch formulations they validate.
# ---------------------------------------------------------------------------


def ragged_attention_ref(
    q: jax.Array,  # (T, nq, hd) flat query stream
    k_pages: jax.Array,  # (N, p, nkv, hd)
    v_pages: jax.Array,
    pos_pages: jax.Array,  # (N, p) int32; -1 = empty
    table: jax.Array,  # (B, P) int32
    row_offsets: jax.Array,  # (n_seg+1,) int32
    seg_slot: jax.Array,  # (n_seg,) int32
    q_pos: jax.Array,  # (T,) int32
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:  # (T, nq, hd); rows past row_offsets[-1] are zero
    """Segment-by-segment oracle: materialize the segment's slot cache from
    its page table, run :func:`attention_ref` on that one segment."""
    import numpy as np

    offs = np.asarray(row_offsets)
    slots = np.asarray(seg_slot)
    T = q.shape[0]
    out = np.zeros(q.shape, np.asarray(q).dtype)
    for s in range(offs.shape[0] - 1):
        lo, hi = int(offs[s]), int(offs[s + 1])
        if hi <= lo:
            continue
        tbl1 = table[int(slots[s]) : int(slots[s]) + 1]  # (1, P)
        kk = paged_gather_ref(
            k_pages.reshape(k_pages.shape[0], k_pages.shape[1], -1), tbl1
        ).reshape(1, -1, *k_pages.shape[2:])
        vv = paged_gather_ref(
            v_pages.reshape(v_pages.shape[0], v_pages.shape[1], -1), tbl1
        ).reshape(1, -1, *v_pages.shape[2:])
        kv_pos = paged_gather_ref(pos_pages[..., None], tbl1)[..., 0]  # (1, ctx)
        seg = attention_ref(
            q[None, lo:hi], kk, vv, q_pos[None, lo:hi], kv_pos,
            causal=causal, window=window, scale=scale,
        )
        out[lo:hi] = np.asarray(seg[0])
    return jnp.asarray(out)


def ragged_gather_rows_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    """out[s, i] = x[idx[s, i]] (zero row where idx < 0), row by row."""
    import numpy as np

    x_np, idx_np = np.asarray(x), np.asarray(idx)
    n_seg, k = idx_np.shape
    out = np.zeros((n_seg, k, x_np.shape[1]), x_np.dtype)
    for s in range(n_seg):
        for i in range(k):
            if idx_np[s, i] >= 0:
                out[s, i] = x_np[idx_np[s, i]]
    return jnp.asarray(out)


def ragged_scatter_add_rows_ref(
    x: jax.Array,  # (T, D)
    idx: jax.Array,  # (n_seg, k) flat indices, unique where >= 0
    delta: jax.Array,  # (n_seg, k, D)
    gate: jax.Array,  # (n_seg, k) f32
) -> jax.Array:
    """out[t] = x[t] + cast(gate * delta) for the at most one (s, i) with
    idx[s, i] == t; masked (-1) selections contribute nothing."""
    import numpy as np

    out = np.asarray(x).copy()
    idx_np = np.asarray(idx)
    gated = np.asarray(gate)[..., None].astype(np.float32) * np.asarray(
        delta
    ).astype(np.float32)
    for s in range(idx_np.shape[0]):
        for i in range(idx_np.shape[1]):
            t = idx_np[s, i]
            if t >= 0:
                out[t] = out[t] + gated[s, i].astype(out.dtype)
    return jnp.asarray(out)


def ragged_paged_scatter_rows_ref(
    pages: jax.Array,  # (N, p, F)
    pid: jax.Array,  # (W,)
    off: jax.Array,  # (W,)
    rows: jax.Array,  # (W, F)
) -> jax.Array:
    """pages[pid[w], off[w]] = rows[w], write by write (valid targets are
    unique by contract; dump-page collisions are garbage by contract)."""
    import numpy as np

    pages_np = np.asarray(pages).copy()
    pid_np, off_np, rows_np = np.asarray(pid), np.asarray(off), np.asarray(rows)
    for w in range(pid_np.shape[0]):
        pages_np[pid_np[w], off_np[w]] = rows_np[w]
    return jnp.asarray(pages_np)


# ---------------------------------------------------------------------------
# Fused routed-block oracles (the "pallas_fused" backend, paper Eq. 1 with
# the dispatch folded into the compute): direct one-pass formulations built
# on the one-hot gather/scatter above.
# ---------------------------------------------------------------------------


def _rmsnorm_ref(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _rope_ref(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = pos[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1).astype(x.dtype)


def routed_attention_ref(
    x: jax.Array,  # (B, S, D) full residual stream
    idx: jax.Array,  # (B, k)
    pos_sub: jax.Array,  # (B, k) original positions of routed rows
    params,  # ln, wq, wk, wv, wo (+ bq, bk, bv)
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    scale: float,
    causal: bool = True,
    window: int = 0,
    rope_theta: float = 10000.0,
    pos_emb: str = "rope",
    eps: float = 1e-5,
) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the fused routed-attention kernel: gather (one-hot) ->
    RMSNorm -> QKV -> RoPE -> masked softmax attention -> out-proj.
    Returns (a_sub, x_sub + a_sub)."""
    B = x.shape[0]
    k = idx.shape[1]
    x_sub = gather_rows_ref(x, idx)
    hn = _rmsnorm_ref(params["ln"], x_sub, eps)
    q, kk, vv = hn @ params["wq"], hn @ params["wk"], hn @ params["wv"]
    if "bq" in params:
        q, kk, vv = q + params["bq"], kk + params["bk"], vv + params["bv"]
    q = q.reshape(B, k, n_heads, head_dim)
    kk = kk.reshape(B, k, n_kv_heads, head_dim)
    vv = vv.reshape(B, k, n_kv_heads, head_dim)
    if pos_emb == "rope":
        q = _rope_ref(q, pos_sub, rope_theta)
        kk = _rope_ref(kk, jnp.maximum(pos_sub, 0), rope_theta)
    valid = pos_sub[:, None, :] >= 0
    if causal:
        valid = valid & (pos_sub[:, None, :] <= pos_sub[:, :, None])
    if window > 0:
        valid = valid & (pos_sub[:, :, None] - pos_sub[:, None, :] < window)
    g = n_heads // n_kv_heads
    qg = q.reshape(B, k, n_kv_heads, g, head_dim)
    s = jnp.einsum("bsngh,btnh->bngst", qg, kk).astype(jnp.float32) * scale
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(vv.dtype)
    o = jnp.einsum("bngst,btnh->bsngh", p, vv).reshape(B, k, n_heads * head_dim)
    a = o @ params["wo"]
    return a, x_sub + a


def routed_mlp_scatter_ref(
    x: jax.Array,  # (B, S, D)
    h_sub: jax.Array,  # (B, k, D)
    a_sub: jax.Array,  # (B, k, D)
    idx: jax.Array,  # (B, k)
    gate: jax.Array,  # (B, k) f32
    params,  # ln, w_up, w_down (+ w_gate)
    act: str = "silu",
    eps: float = 1e-5,
) -> jax.Array:
    """Oracle for the fused routed-MLP kernel: (Swi/Ge)GLU on routed rows,
    then the gated one-hot scatter-add epilogue (Eq. 1 combine)."""
    hn = _rmsnorm_ref(params["ln"], h_sub, eps)
    act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = hn @ params["w_up"]
    up = act_fn(hn @ params["w_gate"]) * up if "w_gate" in params else act_fn(up)
    m = up @ params["w_down"]
    return scatter_add_rows_ref(x, idx, a_sub + m, gate)
