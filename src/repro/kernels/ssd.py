"""Pallas TPU kernel for the Mamba2 SSD intra-chunk quadratic.

The chunked SSD algorithm splits into (a) an intra-chunk attention-like
quadratic — the compute hot-spot, O(Q^2) per chunk with MXU-friendly
matmuls — and (b) a cheap sequential cross-chunk state scan. This kernel
computes (a) plus the per-chunk state increment; (b) stays in lax (it is
latency-, not compute-, bound).

Grid = (B, H, NC): one program per (batch, head, chunk). VMEM working set
per program: x (Q, hd) + B/C (Q, ds) + the (Q, Q) decay/score tile + the
(hd, ds) increment — with Q=128, hd=64, ds=128 this is ~250 KiB, far under
VMEM; Q and ds are 128-multiples for MXU alignment.

Outputs per program:
  y_intra (Q, hd)   = M @ x        where M_ij = C_i.B_j exp(l_i-l_j) dt_j, j<=i
  inc     (hd, ds)  = sum_j exp(l_Q - l_j) dt_j x_j B_j^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, loglam_ref, dt_ref, b_ref, c_ref, y_ref, inc_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, hd)
    loglam = loglam_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Q = x.shape[0]

    l = jnp.cumsum(loglam)  # (Q,)
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_i . B_j
    decay = jnp.exp(l[:, None] - l[None, :])  # l_i - l_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(jj <= ii, CB * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, hd)
    w = jnp.exp(l[-1] - l) * dt  # (Q,)
    inc = jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (hd, ds)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    inc_ref[0, 0, 0] = inc


def ssd_intra_chunk(
    x: jax.Array,  # (B, H, NC, Q, hd)
    loglam: jax.Array,  # (B, H, NC, Q)
    dt: jax.Array,  # (B, H, NC, Q)
    Bm: jax.Array,  # (B, NC, Q, ds)
    Cm: jax.Array,  # (B, NC, Q, ds)
    *,
    interpret: bool = False,
):
    """Returns (y_intra (B,H,NC,Q,hd) f32, inc (B,H,NC,hd,ds) f32)."""
    B, H, NC, Q, hd = x.shape
    ds = Bm.shape[-1]
    grid = (B, H, NC)
    y, inc = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, hd, ds), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, NC, Q, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, NC, hd, ds), jnp.float32),
        ],
        interpret=interpret,
    )(x, loglam, dt, Bm, Cm)
    return y, inc
