"""Pallas TPU kernels for MoD routed dispatch: fused row-gather + gated
scatter-add (the two data-movement halves of paper Eq. 1).

Both kernels express the data-dependent row permutation as a one-hot
selection matmul so the inner loop is pure MXU work and the (B, S, D)
operand streams through VMEM exactly once:

- ``gather_rows(x, idx)``:  out[b, i] = x[b, idx[b, i]]
  grid (B, S/bs); each step folds P_j^T @ x_block into a (k, D) f32
  accumulator, where P_j[i, r] = [idx[b, i] == j*bs + r].
- ``scatter_add_rows(x, idx, delta, gate)``:
  out[b, s] = x[b, s] + cast(gate[b, i] * delta[b, i]) where idx[b, i] == s
  grid (B, S/bs); each output block is x_block + P_j @ (gate * delta),
  fusing the f32 gating multiply into the scatter pass.

Because top-k indices are unique per sequence, every output row receives at
most one contribution, so the f32 one-hot matmuls are *bit-exact* against
the XLA ``take_along_axis`` / ``at[].add`` formulation (validated in
tests/test_routing_backends.py).

Both ops carry a custom VJP (gather's backward is the scatter kernel with a
unit gate; scatter's backward reuses the gather kernel), so the pallas
backend is usable inside the training graph. On CPU the kernels run with
``interpret=True``; on TPU the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.swiglu import _vmem


def _block_s(seq_len: int, block_s: int) -> int:
    """Largest divisor of seq_len that is <= block_s (blocks must tile S)."""
    bs = min(block_s, seq_len)
    while seq_len % bs:
        bs -= 1
    return bs


# ---------------------------------------------------------------------------
# gather: out[b, i, :] = x[b, idx[b, i], :]
# ---------------------------------------------------------------------------


def _gather_kernel(idx_ref, x_ref, o_ref, acc_ref, *, bs: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[0, :]  # (k,)
    k = idx.shape[0]
    # P[i, r] = 1 iff selected row i lives at row r of this S-block
    rows = jax.lax.broadcasted_iota(jnp.int32, (k, bs), 1) + j * bs
    P = (rows == idx[:, None]).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        P,
        x_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _gather_call(x, idx, interpret, block_s):
    B, S, D = x.shape
    k = idx.shape[1]
    bs = _block_s(S, block_s)
    n_blocks = S // bs
    kernel = functools.partial(_gather_kernel, bs=bs, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, k, D), x.dtype),
        scratch_shapes=[_vmem((k, D), jnp.float32)],
        interpret=interpret,
    )(idx, x)


# ---------------------------------------------------------------------------
# gated scatter-add: out[b, s, :] = x[b, s, :] (+ cast(gate * delta) if routed)
# ---------------------------------------------------------------------------


def _scatter_kernel(idx_ref, gate_ref, x_ref, d_ref, o_ref, *, bs: int):
    j = pl.program_id(1)
    idx = idx_ref[0, :]  # (k,)
    k = idx.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bs, k), 0) + j * bs
    P = (rows == idx[None, :]).astype(jnp.float32)  # (bs, k)
    gated = gate_ref[0][:, None] * d_ref[0].astype(jnp.float32)  # (k, D)
    upd = jax.lax.dot_general(
        P, gated, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0] = x_ref[0] + upd.astype(o_ref.dtype)


def _scatter_call(x, idx, delta, gate, interpret, block_s):
    B, S, D = x.shape
    k = idx.shape[1]
    bs = _block_s(S, block_s)
    kernel = functools.partial(_scatter_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, S // bs),
        in_specs=[
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, k), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, k, D), lambda b, j: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        interpret=interpret,
    )(idx, gate.astype(jnp.float32), x, delta)


# ---------------------------------------------------------------------------
# differentiable wrappers (custom VJP; idx is index-valued -> float0 tangent)
# ---------------------------------------------------------------------------


def _float0(idx):
    return np.zeros(idx.shape, jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _gather_rows(x, idx, interpret, block_s):
    return _gather_call(x, idx, interpret, block_s)


def _gather_fwd(x, idx, interpret, block_s):
    return _gather_call(x, idx, interpret, block_s), (idx, x.shape)


def _gather_bwd(interpret, block_s, res, g):
    idx, x_shape = res
    zeros = jnp.zeros(x_shape, g.dtype)
    ones = jnp.ones(idx.shape, jnp.float32)
    dx = _scatter_call(zeros, idx, g, ones, interpret, block_s)
    return dx, _float0(idx)


_gather_rows.defvjp(_gather_fwd, _gather_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _scatter_add_rows(x, idx, delta, gate, interpret, block_s):
    return _scatter_call(x, idx, delta, gate, interpret, block_s)


def _scatter_fwd(x, idx, delta, gate, interpret, block_s):
    return _scatter_call(x, idx, delta, gate, interpret, block_s), (idx, delta, gate)


def _scatter_bwd(interpret, block_s, res, g):
    idx, delta, gate = res
    g_sub = _gather_call(g, idx, interpret, block_s)  # (B, k, D)
    ddelta = (gate[..., None] * g_sub.astype(jnp.float32)).astype(delta.dtype)
    dgate = jnp.sum(
        g_sub.astype(jnp.float32) * delta.astype(jnp.float32), axis=-1
    ).astype(gate.dtype)
    return g, _float0(idx), ddelta, dgate


_scatter_add_rows.defvjp(_scatter_fwd, _scatter_bwd)


def gather_rows(
    x: jax.Array,  # (B, S, D)
    idx: jax.Array,  # (B, k) int32, unique per row
    *,
    interpret: bool = False,
    block_s: int = 256,
) -> jax.Array:  # (B, k, D)
    return _gather_rows(x, idx, interpret, block_s)


def scatter_add_rows(
    x: jax.Array,  # (B, S, D)
    idx: jax.Array,  # (B, k) int32, unique per row
    delta: jax.Array,  # (B, k, D)
    gate: jax.Array,  # (B, k) f32 router weights
    *,
    interpret: bool = False,
    block_s: int = 256,
) -> jax.Array:  # (B, S, D)
    return _scatter_add_rows(x, idx, delta, gate, interpret, block_s)
