"""Pallas TPU kernels for the compute hot-spots.

- flash_attention: causal GQA flash attention with explicit position masks
  (serves both vanilla blocks and MoD's gathered sub-sequences), plus
  routed_attention — the attention half of the "pallas_fused" backend,
  with the MoD gather folded into the kernel prologue
- ssd: Mamba2 SSD intra-chunk kernel (the quadratic hot loop)
- swiglu: fused SwiGLU MLP (gate/up matmuls + silu + down, one VMEM pass),
  plus routed_mlp_scatter — the MLP half of the "pallas_fused" backend,
  with paper Eq. 1's gated scatter-add as the kernel epilogue
- routing: standalone fused MoD row-gather + gated scatter-add (the
  "pallas" backend of the routed-execution engine in core/routing.py, and
  the fallback for non-fusable "pallas_fused" sites)

Each kernel has a pure-jnp oracle in ref.py and a jit'd dispatching wrapper
in ops.py. On this CPU container kernels execute via ``interpret=True``;
on TPU the same pallas_call lowers to Mosaic.

Under SPMD routed execution (DESIGN.md §SPMD routed execution) the
dispatch kernels run *per data shard* inside ``shard_map`` regions on the
shard-local slice of the residual stream; the fused routed-block kernels
additionally require every dim they fuse over (heads, ffn) to be whole on
each device — ``models.blocks.fused_dispatch_supported(cfg, spmd)`` is the
gate, and a mesh that splits a fused dim falls back to the standalone
dispatch kernels around the xla block path.
"""
