"""Pallas TPU kernels for the compute hot-spots.

- flash_attention: causal GQA flash attention with explicit position masks
  (serves both vanilla blocks and MoD's gathered sub-sequences)
- ssd: Mamba2 SSD intra-chunk kernel (the quadratic hot loop)
- swiglu: fused SwiGLU MLP (gate/up matmuls + silu + down, one VMEM pass)
- routing: fused MoD row-gather + gated scatter-add (the "pallas" backend
  of the routed-execution engine in core/routing.py)

Each kernel has a pure-jnp oracle in ref.py and a jit'd dispatching wrapper
in ops.py. On this CPU container kernels execute via ``interpret=True``;
on TPU the same pallas_call lowers to Mosaic.
"""
