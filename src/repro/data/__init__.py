"""Data substrate: deterministic synthetic LM stream, packing, sharded loader."""
from repro.data.synthetic import SyntheticLM  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
