"""Sequence packing: concatenate variable-length documents into fixed-length
training rows with segment ids, and a loss mask that drops cross-document
prediction targets.

Packing is greedy first-fit in arrival order (deterministic). Segment ids
let the attention mask (and the MoD router, which is segment-agnostic by
design — routing weights are per-token) treat documents independently.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Sequence

import numpy as np


def pack_documents(
    docs: Sequence[np.ndarray], seq_len: int, pad_id: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields dict(tokens, labels, segment_ids, loss_mask) rows."""
    buf_toks: List[int] = []
    buf_segs: List[int] = []
    seg = 1
    for doc in docs:
        d = list(map(int, doc))
        while d:
            space = seq_len + 1 - len(buf_toks)
            take, d = d[:space], d[space:]
            buf_toks.extend(take)
            buf_segs.extend([seg] * len(take))
            if len(buf_toks) == seq_len + 1:
                yield _emit(buf_toks, buf_segs, seq_len)
                buf_toks, buf_segs = [], []
        seg += 1
    if buf_toks:
        pad = seq_len + 1 - len(buf_toks)
        buf_toks.extend([pad_id] * pad)
        buf_segs.extend([0] * pad)
        yield _emit(buf_toks, buf_segs, seq_len)


def _emit(toks: List[int], segs: List[int], seq_len: int) -> Dict[str, np.ndarray]:
    t = np.asarray(toks, np.int32)
    s = np.asarray(segs, np.int32)
    same_seg = (s[1:] == s[:-1]) & (s[1:] > 0)
    return {
        "tokens": t[:-1],
        "labels": t[1:],
        "segment_ids": s[:-1],
        "loss_mask": same_seg.astype(np.float32),
    }
