"""Shard-aware host loader: turns the synthetic stream into globally-sharded
jax.Arrays laid out for the mesh, with background prefetch.

In a multi-host deployment each host builds only its addressable shard
(``jax.make_array_from_callback``); in this single-process environment the
same code path produces the fully-addressable array. Prefetch depth 2
overlaps host-side generation with device compute (straggler hiding at the
input layer).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import SyntheticLM


class ShardedLoader:
    def __init__(
        self,
        source: SyntheticLM,
        batch_size: int,
        mesh: Optional[Mesh] = None,
        batch_axes=("data",),
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.source = source
        self.batch_size = batch_size
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _device_put(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        sh = {
            k: NamedSharding(self.mesh, P(self.batch_axes, *(None,) * (v.ndim - 1)))
            for k, v in batch.items()
        }
        return {k: jax.device_put(v, sh[k]) for k, v in batch.items()}

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch(step, self.batch_size)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        step, batch = self._q.get()
        self.step = step + 1
        return self._device_put(batch)

    def close(self):
        self._stop.set()
