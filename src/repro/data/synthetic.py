"""Deterministic synthetic LM corpus with learnable structure.

The paper's pretraining corpus is unavailable offline; benchmarks need data
where (a) losses are reproducible bit-for-bit across runs/restarts and (b)
routing has real signal to learn (some tokens are much easier to predict
than others — the premise of MoD). We generate a two-level process:

- a Zipfian unigram distribution over the vocab (natural-language-like
  marginals), and
- a sparse first-order Markov overlay: each token deterministically implies
  its successor with probability ``p_copy`` (easy tokens), otherwise a fresh
  Zipf draw (hard tokens).

Every sequence is generated counter-based from (seed, sequence_index) — no
global RNG state — so any shard/step can be regenerated independently,
which is what makes checkpoint-restart and elastic rescaling exact.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    def __init__(
        self,
        vocab: int,
        seq_len: int,
        seed: int = 0,
        zipf_a: float = 1.2,
        p_copy: float = 0.5,
    ):
        self.vocab = vocab
        self.seq_len = seq_len
        self.seed = seed
        self.p_copy = p_copy
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        probs = ranks ** (-zipf_a)
        self.probs = probs / probs.sum()
        # fixed successor table: the deterministic "easy" transition
        succ_rng = np.random.default_rng(seed ^ 0x5EED)
        self.successor = succ_rng.permutation(vocab).astype(np.int64)

    def sequence(self, index: int) -> np.ndarray:
        """Deterministic sequence #index (counter-based)."""
        rng = np.random.default_rng((self.seed << 32) ^ index)
        n = self.seq_len + 1  # +1 so tokens/labels are a shifted pair
        fresh = rng.choice(self.vocab, size=n, p=self.probs)
        copy_mask = rng.random(n) < self.p_copy
        seq = np.empty(n, dtype=np.int64)
        seq[0] = fresh[0]
        for t in range(1, n):
            seq[t] = self.successor[seq[t - 1]] if copy_mask[t] else fresh[t]
        return seq

    def batch(self, step: int, batch_size: int, shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Global batch `step`, restricted to this host's shard of sequences."""
        assert batch_size % n_shards == 0
        per = batch_size // n_shards
        base = step * batch_size + shard * per
        seqs = np.stack([self.sequence(base + i) for i in range(per)])
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }
