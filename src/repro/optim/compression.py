"""Gradient compression for cross-replica reduction (int8 + error feedback).

In pjit data parallelism the gradient all-reduce is implicit; to compress it
we take explicit control inside ``shard_map`` over the data axes: quantize
the local gradient to int8 with a per-tensor f32 scale, ``psum`` the int8
payload (XLA upcasts the accumulator, wire format stays 1 byte/elem), and
dequantize. Error feedback (Seide et al., 2014) carries the quantization
residual into the next step so the compressed SGD direction stays unbiased
in the long run.

Used by the train loop when ``optim.grad_compression="int8"``; the dry-run
baseline keeps it off so roofline tables reflect the uncompressed schedule
(§Perf records the compressed variant as an optimization experiment).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    local_grad: Any, axis_name, error: Any
) -> Tuple[Any, Any]:
    """int8-compressed psum with error feedback.

    Must run inside shard_map with ``axis_name`` mapped. Returns
    (mean-reduced grads, new error feedback state).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        # agree on ONE scale across the axis (scalar pmax — 4 wire bytes),
        # then quantize: dequantization is exact w.r.t. that shared scale
        scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_e = g32 - q.astype(jnp.float32) * scale
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        avg = qsum.astype(jnp.float32) * scale / n
        return avg.astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(local_grad)
    flat_e = td.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(td, [o[0] for o in out]),
        jax.tree.unflatten(td, [o[1] for o in out]),
    )


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
