"""Decoupled AdamW on raw pytrees (no optax dependency).

Moments are kept in f32 regardless of param dtype (bf16 training needs f32
state); the update is computed in f32 and cast back. Weight decay is
decoupled and skipped for 1-D params (norm scales, biases, routers) per
standard practice.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimConfig

OptState = Dict[str, Any]


def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _decay_mask(p: jax.Array) -> jnp.float32:
    return jnp.float32(1.0 if p.ndim >= 2 else 0.0)


def adamw_update(
    params: Any,
    grads: Any,
    opt: OptState,
    cfg: OptimConfig,
    lr: jax.Array,
) -> Tuple[Any, OptState]:
    count = opt["count"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * _decay_mask(p) * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
