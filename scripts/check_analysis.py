#!/usr/bin/env python
"""CI ``analysis`` stage driver: modlint + (when installed) ruff + mypy.

Three gates, in order:

1. ``modlint`` (src/repro/analysis): the repo-specific trace-safety /
   jit-cache / Pallas kernel-contract rules over ``src`` and ``scripts``,
   ratcheted against the committed ``analysis_baseline.json``. Always
   runs — it needs nothing beyond the stdlib ``ast`` module (no JAX
   execution), which is why the stage is fast enough for ``--fast``.
2. ``ruff`` (pycodestyle/pyflakes/bugbear subset, configured in
   pyproject.toml) over ``src/repro/serve`` and ``src/repro/analysis``.
3. ``mypy`` (configured in pyproject.toml) over the same two trees.

ruff/mypy are dev dependencies (requirements-dev.txt). The pinned local
container may not ship them; a missing tool is reported as SKIP, not a
failure — the GitHub Actions analysis lane installs requirements-dev.txt
and therefore always runs all three.

Exit status: nonzero iff any gate that ran failed.
"""

from __future__ import annotations

import importlib.util
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODLINT_PATHS = ["src", "scripts"]
LINT_PATHS = ["src/repro/serve", "src/repro/analysis"]


def have_tool(module: str) -> bool:
    return importlib.util.find_spec(module) is not None


def run_modlint() -> int:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.analysis import main as modlint_main

    print("[analysis] modlint: python -m repro.analysis", *MODLINT_PATHS)
    return modlint_main(MODLINT_PATHS)


def run_ruff() -> int:
    if not have_tool("ruff"):
        print("[analysis] ruff: SKIP (not installed — pip install -r "
              "requirements-dev.txt)")
        return 0
    cmd = [sys.executable, "-m", "ruff", "check", *LINT_PATHS]
    print("[analysis] ruff:", " ".join(cmd[2:]))
    return subprocess.call(cmd, cwd=REPO)


def run_mypy() -> int:
    if not have_tool("mypy"):
        print("[analysis] mypy: SKIP (not installed — pip install -r "
              "requirements-dev.txt)")
        return 0
    cmd = [sys.executable, "-m", "mypy", "--config-file", "pyproject.toml"]
    print("[analysis] mypy:", " ".join(cmd[2:]))
    return subprocess.call(cmd, cwd=REPO)


def main() -> int:
    os.chdir(REPO)
    failures = []
    for name, gate in (("modlint", run_modlint), ("ruff", run_ruff),
                       ("mypy", run_mypy)):
        rc = gate()
        if rc != 0:
            failures.append(name)
            print(f"[analysis] {name}: FAILED (exit {rc})")
    if failures:
        print(f"[analysis] FAILED: {', '.join(failures)}")
        return 1
    print("[analysis] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
