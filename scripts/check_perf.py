#!/usr/bin/env python3
"""Perf-regression gate over committed BENCH_<pr>.json snapshots.

``benchmarks/run.py --snapshot BENCH_<pr>.json`` records the
``D:mod-dispatch`` and ``S:serving`` cells of ``results/perf_log.json``
into a committed snapshot; this script is the CI gate over them:

1. **Structure** — the current snapshot must carry all three
   ``D:mod-dispatch`` backends (xla | pallas | pallas_fused) and at least
   one ``S:serving`` cell.
2. **Fused-dispatch claim** (deterministic, the acceptance criterion of
   the pallas_fused backend) — ``pallas_fused`` must report strictly fewer
   HBM round trips of the (B, S, D) residual stream than both other
   backends, and zero standalone gather/scatter cells.
3. **Tolerance vs the previous snapshot** — wall-clock cells
   (``dispatch_us``/``block_us``, serving ``tokens_per_s`` /
   ``latency_p95_steps``) may not regress beyond ``--tolerance``
   (default 0.5: CPU wall-clocks are noisy; the structural counts are the
   hard gate). First snapshot -> comparison is skipped.

  python scripts/check_perf.py                 # discover BENCH_*.json
  python scripts/check_perf.py --tolerance 0.3
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

DISPATCH_CELL = "D:mod-dispatch"
SERVING_CELL = "S:serving"
BACKENDS = ("xla", "pallas", "pallas_fused")

# metric -> direction ("min": larger is a regression; "max": smaller is)
WALL_CLOCK_METRICS = {
    "dispatch_us": "min",
    "block_us": "min",
    "tokens_per_s": "max",
    "latency_p95_steps": "min",
}


def discover_snapshots(root: str) -> List[Tuple[int, str]]:
    out = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
        if m:
            out.append((int(m.group(1)), path))
    return sorted(out)


def load_cells(path: str) -> List[Dict]:
    with open(path) as f:
        data = json.load(f)
    return data["cells"] if isinstance(data, dict) else data


def cell_index(cells: List[Dict]) -> Dict[Tuple[str, str], Dict]:
    return {(str(e.get("cell", "")), str(e.get("name", ""))): e for e in cells}


def check_structure(cells: List[Dict]) -> List[str]:
    errors = []
    idx = cell_index(cells)
    for b in BACKENDS:
        if (DISPATCH_CELL, b) not in idx:
            errors.append(f"missing {DISPATCH_CELL} cell for backend {b!r}")
    if not any(c == SERVING_CELL for c, _ in idx):
        errors.append(f"no {SERVING_CELL} cells in snapshot")
    # paged-pool cells (PR 5+): every *-paged-* serving cell must carry the
    # page-utilization + prefix-hit telemetry; at least one must exist.
    # First appearance is fine for the tolerance gate (check_regression
    # reports baseline-less cells as "new", never failed).
    paged = [e for (c, n), e in idx.items()
             if c == SERVING_CELL and "-paged" in n]
    if not paged:
        errors.append(f"no paged {SERVING_CELL} cells in snapshot "
                      "(benchmarks/serving.py --page-size)")
    for e in paged:
        for k in ("page_utilization", "prefix_hit_rate", "paged_tokens_ratio"):
            if k not in e:
                errors.append(f"{SERVING_CELL}/{e.get('name')}: missing {k}")
    # ragged flat-token cells (PR 6+): the mixed prefill+decode sweep must
    # exist for both engines, the ragged cell must beat its padded twin
    # (the tentpole acceptance criterion — structural, not tolerance-gated),
    # and the once-compiled-step contract must hold.
    mixed_ragged = [e for (c, n), e in idx.items()
                    if c == SERVING_CELL and "-mixed-ragged" in n]
    if not mixed_ragged:
        errors.append(f"no mixed-ragged {SERVING_CELL} cells in snapshot "
                      "(benchmarks/serving.py --ragged)")
    for e in mixed_ragged:
        name = e.get("name")
        ratio = e.get("ragged_vs_padded_ratio")
        if ratio is None:
            errors.append(f"{SERVING_CELL}/{name}: missing ragged_vs_padded_ratio")
        elif float(ratio) <= 1.0:
            errors.append(
                f"{SERVING_CELL}/{name}: ragged_vs_padded_ratio {ratio:.3f} "
                "<= 1.0 (mixed ragged cell must beat the padded engine)"
            )
        if "padded_token_fraction" not in e:
            errors.append(f"{SERVING_CELL}/{name}: missing padded_token_fraction")
        dc = e.get("decode_compilations")
        if dc is not None and float(dc) > 1:
            errors.append(
                f"{SERVING_CELL}/{name}: decode_compilations {dc} > 1 "
                "(the mixed step must trace at most once)"
            )
    # self-speculative cells (PR 7+): the sweep must exist, every cell
    # carries the accept telemetry and keeps the once-compiled contract,
    # and the tentpole acceptance criterion holds: the *best* cell beats
    # its plain greedy baseline. Best, not all — the sweep includes
    # degenerate draft ratios on purpose, and the MoD model's small
    # routing ops serialized inside the verify scan can eat the dispatch-
    # amortization win at CPU tiny-scale (same caveat as the
    # mod_vs_dense_speedup line; the bit-identity contract is tested for
    # both families regardless).
    spec = [e for (c, n), e in idx.items()
            if c == SERVING_CELL and "-spec-n" in n]
    if not spec:
        errors.append(f"no speculative {SERVING_CELL} cells in snapshot "
                      "(benchmarks/serving.py speculative_sweep)")
    best_ratio = 0.0
    for e in spec:
        name = str(e.get("name"))
        for k in ("speculative_accept_rate", "speculative_tokens_per_round",
                  "spec_vs_plain_ratio"):
            if k not in e:
                errors.append(f"{SERVING_CELL}/{name}: missing {k}")
        dc = e.get("decode_compilations")
        if dc is not None and float(dc) > 1:
            errors.append(
                f"{SERVING_CELL}/{name}: decode_compilations {dc} > 1 "
                "(the speculative step must trace at most once)"
            )
        ratio = e.get("spec_vs_plain_ratio")
        if ratio is not None:
            best_ratio = max(best_ratio, float(ratio))
    if spec and best_ratio <= 1.0:
        errors.append(
            f"best speculative cell: spec_vs_plain_ratio {best_ratio:.3f} "
            "<= 1.0 (some (n, draft_ratio) must beat plain greedy decode)"
        )
    # overload-control cells (PR 8+): the p99-vs-offered-load curve must
    # exist for >= 2 loads x both controller modes, and the tentpole
    # acceptance criterion holds at the highest load. The gated latency
    # unit is FLOP-priced steps (p99_latency_cost): each engine step is
    # priced by the capacity ladder's analytic FLOP ratio, which is where
    # MoD degradation pays — steps don't get fewer under a capacity cut,
    # they get cheaper, and open-loop arrivals + token-budget requests
    # make both numbers deterministic. Raw step-domain p99 is gated too,
    # with a +2-step allowance: the degraded per-wave admission budget
    # may delay a batch-tier admission by a step when slots free together.
    errors += check_overload_claim(cells)
    # quantized-KV cells (PR 9+): the int8/fp8 paged-KV sweep must exist
    # for all three model families, and the tentpole acceptance criteria
    # hold per cell: pool KV bytes shrink >= 1.7x vs the fp32 twin, greedy
    # drift stays bounded, and the quantized xla and pallas backends are
    # bit-identical (the fused-dequant kernels against the reference path).
    errors += check_quant_claim(cells)
    return errors


def check_overload_claim(cells: List[Dict],
                         step_allowance: float = 2.0) -> List[str]:
    """The overload-control acceptance criteria, gated structurally."""
    errors = []
    curves: Dict[str, Dict[float, Dict]] = {"static": {}, "adaptive": {}}
    identity = []
    for e in cells:
        if str(e.get("cell")) != SERVING_CELL:
            continue
        name = str(e.get("name", ""))
        if "-overload-latency-identity" in name:
            identity.append(e)
        elif "-overload-" in name:
            mode = "adaptive" if "-overload-adaptive" in name else "static"
            if e.get("offered_load") is None:
                errors.append(f"{SERVING_CELL}/{name}: missing offered_load")
                continue
            curves[mode][float(e["offered_load"])] = e
    for mode, pts in curves.items():
        if len(pts) < 2:
            errors.append(
                f"overload curve needs >= 2 loads for mode {mode!r}, "
                f"got {sorted(pts)} (benchmarks/serving.py overload_sweep)"
            )
    shared = sorted(set(curves["static"]) & set(curves["adaptive"]))
    if not shared:
        if not errors:
            errors.append("static and adaptive overload curves share no "
                          "offered_load points")
        return errors
    for load in shared:
        for mode in ("static", "adaptive"):
            e = curves[mode][load]
            for k in ("p99_latency_steps", "p99_latency_cost", "shed",
                      "degraded_decode_steps", "capacity_level_max"):
                if k not in e:
                    errors.append(
                        f"{SERVING_CELL}/{e.get('name')}: missing {k}")
    if errors:
        return errors
    top = shared[-1]
    st, ad = curves["static"][top], curves["adaptive"][top]
    if float(ad["p99_latency_cost"]) > float(st["p99_latency_cost"]):
        errors.append(
            f"overload load {top:g}: adaptive p99_latency_cost "
            f"{float(ad['p99_latency_cost']):.2f} > static "
            f"{float(st['p99_latency_cost']):.2f} (the ladder must not "
            "worsen FLOP-priced tail latency at the highest load)"
        )
    if float(ad["p99_latency_steps"]) > (
        float(st["p99_latency_steps"]) + step_allowance
    ):
        errors.append(
            f"overload load {top:g}: adaptive p99_latency_steps "
            f"{float(ad['p99_latency_steps']):.2f} > static + "
            f"{step_allowance:g} ({float(st['p99_latency_steps']):.2f})"
        )
    if not float(ad.get("shed", 0)) > 0:
        errors.append(f"overload load {top:g}: adaptive curve shed nothing "
                      "(bounded backpressure never engaged)")
    if not float(ad.get("degraded_decode_steps", 0)) > 0:
        errors.append(f"overload load {top:g}: adaptive curve never ran a "
                      "degraded decode step")
    if not float(ad.get("capacity_level_max", 0)) >= 1:
        errors.append(f"overload load {top:g}: adaptive controller never "
                      "left level 0")
    if not identity:
        errors.append(f"no {SERVING_CELL} latency-identity cell "
                      "(benchmarks/serving.py overload_latency_identity)")
    for e in identity:
        if float(e.get("latency_identical", 0.0)) != 1.0:
            errors.append(
                f"{SERVING_CELL}/{e.get('name')}: latency_identical "
                f"{e.get('latency_identical')} != 1.0 (latency-tier streams "
                "must be bit-identical under adaptive overload)"
            )
    return errors


def check_quant_claim(cells: List[Dict],
                      min_kv_ratio: float = 1.7,
                      max_flip_rate: float = 0.25) -> List[str]:
    """The quantized-paged-KV acceptance criteria, gated structurally.

    ``min_kv_ratio`` is the tentpole's memory bound: fp32 pool KV bytes
    over quantized (narrow pages + f32 scales; int8 measures ~3.9x at
    page_size 4). ``max_flip_rate`` bounds greedy drift — the measured
    smoke/full rates are 0.0, so 0.25 is a loose cap that still catches a
    broken quantizer (random logits flip ~every token). Identity and the
    once-compiled contract are exact.
    """
    errors = []
    quant = [e for e in cells
             if str(e.get("cell")) == SERVING_CELL
             and "-quant-" in str(e.get("name", ""))]
    if not quant:
        return [f"no quantized {SERVING_CELL} cells in snapshot "
                "(benchmarks/serving.py quant_sweep)"]
    for fam in ("mod", "dense", "moe"):
        if not any(str(e.get("name", "")).startswith(f"{fam}-quant-")
                   for e in quant):
            errors.append(f"no {fam}-quant-* {SERVING_CELL} cell in snapshot")
    for e in quant:
        name = e.get("name")
        missing = [k for k in ("quant_kv", "quant_scale", "kv_bytes",
                               "resid_bytes", "kv_bytes_per_token",
                               "kv_bytes_ratio", "logit_mad",
                               "token_flip_rate", "quant_identity")
                   if k not in e]
        for k in missing:
            errors.append(f"{SERVING_CELL}/{name}: missing {k}")
        if missing:
            continue
        ratio = float(e["kv_bytes_ratio"])
        if ratio < min_kv_ratio:
            errors.append(
                f"{SERVING_CELL}/{name}: kv_bytes_ratio {ratio:.3f} < "
                f"{min_kv_ratio:g} (quantized pool must cut KV bytes)"
            )
        flip = float(e["token_flip_rate"])
        if flip > max_flip_rate:
            errors.append(
                f"{SERVING_CELL}/{name}: token_flip_rate {flip:.3f} > "
                f"{max_flip_rate:g} (quantization drift out of bounds)"
            )
        if float(e["quant_identity"]) != 1.0:
            errors.append(
                f"{SERVING_CELL}/{name}: quant_identity "
                f"{e['quant_identity']} != 1.0 (quantized xla and pallas "
                "streams must be bit-identical)"
            )
        dc = e.get("decode_compilations")
        if dc is not None and float(dc) > 1:
            errors.append(
                f"{SERVING_CELL}/{name}: decode_compilations {dc} > 1 "
                "(the quantized decode step must trace at most once)"
            )
    return errors


def check_fused_claim(cells: List[Dict]) -> List[str]:
    """The dispatch-fusion acceptance criterion, gated structurally."""
    errors = []
    idx = cell_index(cells)
    trips = {}
    for b in BACKENDS:
        e = idx.get((DISPATCH_CELL, b), {})
        if "hbm_round_trips" not in e:
            errors.append(f"{DISPATCH_CELL}/{b}: no hbm_round_trips recorded")
            continue
        trips[b] = float(e["hbm_round_trips"])
    if "pallas_fused" in trips:
        others = [trips[b] for b in ("xla", "pallas") if b in trips]
        if not others or not all(trips["pallas_fused"] < t for t in others):
            errors.append(
                f"pallas_fused round trips ({trips.get('pallas_fused')}) not "
                f"strictly below xla/pallas ({others})"
            )
        cells_count = idx[(DISPATCH_CELL, "pallas_fused")].get(
            "standalone_dispatch_cells"
        )
        if cells_count != 0:
            errors.append(
                f"pallas_fused reports {cells_count} standalone dispatch "
                "cells (want 0: gather/scatter must ride the compute kernels)"
            )
    return errors


def check_regression(
    cur: List[Dict], prev: List[Dict], tolerance: float
) -> Tuple[List[str], List[str]]:
    """Per-cell delta table vs the previous snapshot.

    Cells that exist only in the current run (e.g. a cell added this PR —
    the baseline predates it) are reported as ``new``, never failed: a
    snapshot lacking a cell the current run has is expected exactly once,
    on the PR that introduces the cell.
    """
    errors, report = [], []
    prev_idx = cell_index(prev)
    w = max((len(f"{c.get('cell', '')}/{c.get('name', '')}") for c in cur), default=20)
    for e in cur:
        key = (str(e.get("cell", "")), str(e.get("name", "")))
        label = f"{key[0]}/{key[1]}".ljust(w)
        base = prev_idx.get(key)
        if base is None:
            report.append(f" new  {label}  (no baseline cell — added this PR)")
            continue
        # a sharded-dispatch cell measured at a different data_shards (the
        # 1-device vs forced-8-device lanes) is a different quantity, not a
        # regression — report, don't compare
        if "data_shards" in e and "data_shards" in base and float(
            e["data_shards"]
        ) != float(base["data_shards"]):
            report.append(
                f"skip  {label}  (data_shards {base['data_shards']:.0f} -> "
                f"{e['data_shards']:.0f}: different lane, not comparable)"
            )
            continue
        for metric, direction in WALL_CLOCK_METRICS.items():
            if metric not in e:
                continue
            if metric not in base:
                report.append(f" new  {label}  {metric} (not in baseline)")
                continue
            # a null metric (e.g. p95 latency on a cell with no finished
            # latency-tier requests) is "not measured", not a regression
            if e[metric] is None or base[metric] is None:
                continue
            now, then = float(e[metric]), float(base[metric])
            if then <= 0:
                continue
            ratio = now / then
            bad = ratio > 1 + tolerance if direction == "min" else ratio < 1 - tolerance
            report.append(
                f"{'FAIL' if bad else ' ok '} {label}  {metric:>17}: "
                f"{then:10.2f} -> {now:10.2f}  ({ratio:5.2f}x, "
                f"{'min' if direction == 'min' else 'max'})"
            )
            if bad:
                errors.append(report[-1].strip())
    return errors, report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default=None, help="snapshot to validate "
                    "(default: highest-numbered BENCH_*.json)")
    ap.add_argument("--previous", default=None, help="baseline snapshot "
                    "(default: second-highest BENCH_*.json)")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional wall-clock regression")
    ap.add_argument("--root", default=os.path.join(os.path.dirname(__file__), ".."))
    args = ap.parse_args()

    root = os.path.abspath(args.root)
    snaps = discover_snapshots(root)
    current: Optional[str] = args.current or (snaps[-1][1] if snaps else None)
    previous: Optional[str] = args.previous or (
        snaps[-2][1] if len(snaps) > 1 else None
    )
    if current is None:
        # A repo state with no snapshots (fresh clone of an early PR, or a
        # CI container without the committed BENCH files) has nothing to
        # gate — that is a skip, not a failure.
        print("[check_perf] SKIP: no BENCH_*.json snapshot found; nothing to "
              "gate (create one with: python -m benchmarks.run --quick "
              "--only serving --snapshot BENCH_<pr>.json)")
        return 0
    if not os.path.exists(current):
        print(f"[check_perf] FAIL: snapshot {current} does not exist")
        return 1

    cells = load_cells(current)
    errors = check_structure(cells) + check_fused_claim(cells)
    print(f"[check_perf] current: {os.path.basename(current)} ({len(cells)} cells)")

    if previous is not None:
        reg_errors, report = check_regression(
            cells, load_cells(previous), args.tolerance
        )
        print(f"[check_perf] baseline: {os.path.basename(previous)} "
              f"(tolerance {args.tolerance:.0%})")
        for line in report:
            print(f"[check_perf]   {line}")
        errors += reg_errors
    else:
        print("[check_perf] no previous snapshot — regression comparison skipped")

    for err in errors:
        print(f"[check_perf] FAIL: {err}")
    if not errors:
        print("[check_perf] OK: structure + fused-dispatch claim"
              + ("" if previous is None else " + tolerance gate"))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
