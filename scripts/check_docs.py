"""Docs CI: every relative markdown link in the top-level docs must resolve.

Scans README.md / DESIGN.md / ROADMAP.md / PAPER.md for ``[text](target)``
links, strips anchors, and fails if a non-URL target doesn't exist on disk
(relative to the file containing the link). Keeps the README's architecture
map and benchmark table honest as files move between PRs.

  python scripts/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = ("README.md", "DESIGN.md", "ROADMAP.md", "PAPER.md")
LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    bad = []
    for doc in DOCS:
        path = root / doc
        if not path.exists():
            bad.append(f"{doc}: missing")
            continue
        for target in LINK.findall(path.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:  # pure in-page anchor
                continue
            if not (path.parent / rel).exists():
                bad.append(f"{doc}: broken link -> {target}")
    for b in bad:
        print(f"[check_docs] {b}", file=sys.stderr)
    if not bad:
        print(f"[check_docs] {len(DOCS)} docs ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
