#!/usr/bin/env bash
# Tier-1 CI: full test suite (includes the routing-backend equivalence
# tests) on CPU, plus the perf-regression gate over the committed
# BENCH_*.json snapshots and a docs step — markdown link check and the
# quickstart example as an executable smoke test. Pallas kernels (incl.
# the pallas_fused routed-attention/-MLP kernels) run in interpret mode
# here; TPU runs use the same entry point without JAX_PLATFORMS.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m pytest -x -q tests/test_routing_backends.py
# fused-dispatch kernels again in isolation (interpret=True on CPU)
python -m pytest -x -q tests/test_routing_backends.py -k "fused"

# perf: committed BENCH_*.json snapshots must keep the fused-dispatch
# round-trip claim and stay within tolerance of the previous snapshot
python scripts/check_perf.py

# docs: README/DESIGN relative links must resolve; quickstart must run
python scripts/check_docs.py
QUICKSTART_STEPS=10 python examples/quickstart.py
