#!/usr/bin/env bash
# Tier-1 CI, in named timed stages shared by local runs and the GitHub
# workflow lanes (.github/workflows/ci.yml):
#
#   analysis  static analysis: modlint (python -m repro.analysis — the
#             repo-specific trace-safety / jit-cache / Pallas
#             kernel-contract rules, ratcheted against
#             analysis_baseline.json) plus ruff+mypy when installed
#             (requirements-dev.txt). Pure AST work, no JAX execution —
#             runs first and in --fast mode too
#   unit      full pytest suite on one CPU device (pallas in interpret mode)
#             — includes tests/test_paged.py: paged-vs-contiguous token
#             identity, prefix-cache reuse, page-exhaustion preemption —
#             plus the serving-stack coverage floor when pytest-cov is
#             installed (requirements-dev.txt)
#   backends  routing-backend equivalence tests (incl. fused kernels),
#             paged gather/scatter kernel oracles and the ragged
#             flat-token kernel family (interpret mode) in isolation
#   spmd      SPMD routed execution on a real 8-device CPU mesh
#             (XLA_FLAGS=--xla_force_host_platform_device_count=8 in a
#             fresh process: test_routing_spmd + test_sharding +
#             test_pipeline)
#   soak      differential engine soak (tests/test_serve_soak.py): fuzzed
#             workloads must stream identically across padded / ragged /
#             speculative engines; hard wall-clock bound so a wedged
#             engine fails instead of hanging CI
#   faults    seeded fault-matrix soak (tests/test_faults.py): injected
#             NaN/Inf logits, page exhaustion, stragglers and preemption
#             storms must fail only the targeted request while pool and
#             scheduler invariants hold; hard wall-clock bound
#   perf      scripts/check_perf.py gate over committed BENCH_*.json
#   docs      markdown link check + quickstart as an executable smoke test
#
#   scripts/ci.sh            # all stages
#   scripts/ci.sh --fast     # analysis+unit+backends+spmd+soak+faults only
#                            # (no perf/docs); needs no network, no BENCH
#                            # files
#
# Extra args after the flags are passed to the unit-stage pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
if [[ "${1:-}" == "--fast" ]]; then
  FAST=1
  shift
fi

STAGE_T0=0
stage() {
  STAGE_T0=$SECONDS
  echo "=== [ci:$1] ==="
}
stage_done() {
  echo "=== [ci:$1] ok (${2}s) ==="
}

# serving-stack coverage rides the unit stage when pytest-cov is
# importable (requirements-dev.txt installs it; the pinned local
# container may lack it, in which case the suite runs uninstrumented).
# The fail-under floor is a ratchet: raise it as the suite grows, never
# lower it to make a red build green.
HAVE_COV=0
python -c "import pytest_cov" >/dev/null 2>&1 && HAVE_COV=1
COV_ARGS=""
if [[ "$HAVE_COV" == 1 ]]; then
  COV_ARGS="--cov=repro.serve --cov-report=term
            --cov-report=xml:coverage-serve.xml --cov-fail-under=70"
fi

stage analysis
# static gates before anything compiles: modlint needs only the stdlib
# ast module, so a trace-safety or kernel-contract violation fails in
# seconds, not after the test lanes
python scripts/check_analysis.py
stage_done analysis $((SECONDS - STAGE_T0))

stage unit
python -m pytest -x -q $COV_ARGS --ignore=tests/test_serve_soak.py \
  --ignore=tests/test_faults.py "$@"
stage_done unit $((SECONDS - STAGE_T0))

stage backends
python -m pytest -x -q tests/test_routing_backends.py
# fused-dispatch kernels again in isolation (interpret=True on CPU)
python -m pytest -x -q tests/test_routing_backends.py -k "fused"
# paged-pool gather/scatter kernels vs the ref.py oracles
python -m pytest -x -q tests/test_paged.py -k "kernels"
# ragged flat-token kernels (interpret=True) vs their dense oracles
python -m pytest -x -q tests/test_ragged.py
# quantized-KV layer: pow2 scale math + fused-dequant kernel oracles, and
# the engine's quantized xla==pallas identity smoke (the fused in-kernel
# dequant against the reference dequant path must stream identical bits)
python -m pytest -x -q tests/test_quant.py \
  -k "pow2 or idempotent or kernels or oracle or xla_pallas"
stage_done backends $((SECONDS - STAGE_T0))

stage spmd
# a real 8-device CPU mesh needs the flag set before jax initializes, so
# this stage always runs in a fresh interpreter
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m pytest -x -q tests/test_routing_spmd.py tests/test_sharding.py \
  tests/test_pipeline.py
stage_done spmd $((SECONDS - STAGE_T0))

stage soak
# seeded differential fuzz over every engine variant; `timeout` turns a
# hung engine (scheduler livelock, device deadlock) into a failure
timeout 600 python -m pytest -x -q tests/test_serve_soak.py
stage_done soak $((SECONDS - STAGE_T0))

stage faults
# seeded fault matrix threaded through live engines (padded / ragged /
# speculative): every injected fault must terminate only its targeted
# request with the right finish_reason while the pool stays balanced;
# `timeout` keeps an engine wedged by its own fault handling from
# hanging CI
timeout 300 python -m pytest -x -q tests/test_faults.py
stage_done faults $((SECONDS - STAGE_T0))

if [[ "$FAST" == "1" ]]; then
  echo "=== [ci] --fast: skipping perf+docs stages ==="
  exit 0
fi

stage perf
# committed BENCH_*.json snapshots must keep the fused-dispatch round-trip
# claim and stay within tolerance of the previous snapshot
python scripts/check_perf.py
stage_done perf $((SECONDS - STAGE_T0))

stage docs
# README/DESIGN relative links must resolve; quickstart must run
python scripts/check_docs.py
QUICKSTART_STEPS=10 python examples/quickstart.py
stage_done docs $((SECONDS - STAGE_T0))
