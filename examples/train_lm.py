"""End-to-end training driver example: a ~100M-parameter MoD LM with
checkpoint/restart, driven through the production launcher. The config is
the paper's smallest isoFLOP setting (12.5% capacity, every other block,
co-trained predictor — §3.1/Fig. 3); at full scale its loss curve is the
MoD side of the isoFLOP comparison in benchmarks/isoflop.py.

Full-size invocation (a few hundred steps of the paper-style 110M model —
hours on this CPU container, minutes on a v5e slice):

  PYTHONPATH=src python examples/train_lm.py --full

Default invocation runs the same code path at smoke scale (~2 min CPU) and
demonstrates kill/resume fault tolerance.
"""
import argparse
import subprocess
import sys
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)


def run(args_list):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-m", "repro.launch.train"] + args_list,
                          env=env, cwd=ROOT)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="train mod-paper-220m (paper scale) instead of smoke")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    if args.full:
        # the paper's ~220M configuration: 2048 seq, batch 128 (§3.6)
        steps = args.steps or 300
        cmd = ["--arch", "mod-paper-220m", "--steps", str(steps),
               "--batch", "128", "--seq", "2048", "--microbatches", "8",
               "--ckpt-dir", args.ckpt_dir]
        sys.exit(run(cmd).returncode)

    steps = args.steps or 60
    base = ["--arch", "mod-paper-60m", "--smoke", "--seq", "128",
            "--batch", "8", "--ckpt-dir", args.ckpt_dir]
    # phase 1: train half the steps, checkpointing
    print("== phase 1: train to step", steps // 2)
    r = run(base + ["--steps", str(steps // 2)])
    assert r.returncode == 0
    # phase 2: 'crash' happened — a fresh process resumes from the manager
    print("== phase 2: resume (fault-tolerance demo) to step", steps)
    r = run(base + ["--steps", str(steps)])
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
