"""Autoregressive sampling with causal MoD routing (paper §3.5).

Trains a small MoD model, then contrasts:
  - teacher-forced scoring with (non-causal) expert-choice top-k routing,
  - token-by-token decoding where the trained *predictor* makes every
    routing decision causally (batch-capacity form),
and prints the router-decision agreement — the paper's claim is that the
predictor mimics top-k almost perfectly, so quality barely degrades.

  PYTHONPATH=src python examples/sample_mod.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny_config, train_bench
from repro.models import api
from repro.train.serve import greedy_generate

cfg = tiny_config(mod=True)
print("training a small MoD model (~1 min)...")
r = train_bench(cfg, steps=80)
params = r["_state"]["params"]
data = r["_data"]

batch = {k: jnp.asarray(v[:4, :64]) for k, v in data.batch(50_000, 8).items()}
toks = batch["tokens"]

# teacher-forced, non-causal top-k (training path)
loss, aux = api.model_loss(params, cfg, {"tokens": toks, "labels": batch["labels"][:, :64]})
print(f"top-k (non-causal) ce: {float(aux['ce']):.4f}")
print(f"predictor accuracy:    {float(aux['mod/predictor_acc']):.4f} (paper: >=0.97)")

# causal decode scoring
B, S = toks.shape
caches = api.make_caches(cfg, B, S + 4)
step = jax.jit(lambda p, c, t, q: api.model_decode(p, c, cfg, t, q))
nll, routed = 0.0, []
for t in range(S - 1):
    logits, caches, a = step(params, caches, toks[:, t : t + 1], jnp.full((B,), t, jnp.int32))
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll -= float(jnp.mean(jnp.take_along_axis(lp, toks[:, t + 1][:, None], -1)))
    routed.append(float(a["mod/decode_routed_frac"]))
print(f"causal decode ce:      {nll / (S - 1):.4f}")
print(f"decode routed frac:    {np.mean(routed):.3f} (capacity {cfg.mod.capacity_ratio})")

out = greedy_generate(params, cfg, toks[:1, :16], n_tokens=16)
print("sampled continuation:", out[0, 16:].tolist())
