"""Quickstart: train a small Mixture-of-Depths LM in ~a minute on CPU.

Shows the public API end to end: config -> init -> jitted train step ->
MoD routing telemetry -> autoregressive sampling with *causal* routing.
Exercises the paper's core mechanics at toy scale: 12.5%-capacity routed
blocks every other layer (§3.1 optimum), the aux-loss router centering
sigmoid(r) on 0.5 (Fig. 5), the co-trained causal predictor (§3.5), and
sampling through the serving engine's batch-capacity routing (Fig. 6).

  PYTHONPATH=src python examples/quickstart.py
  QUICKSTART_STEPS=10 PYTHONPATH=src python examples/quickstart.py  # CI smoke
"""
import os

import jax
import jax.numpy as jnp

from repro.config import (
    AttentionConfig,
    MoDConfig,
    ModelConfig,
    OptimConfig,
    TrainConfig,
)
from repro.data.synthetic import SyntheticLM
from repro.models import api
from repro.train.loop import make_train_state, make_train_step
from repro.train.serve import greedy_generate

# 1. A model config with MoD as a first-class feature: 12.5%-capacity
#    routed blocks interleaved with full blocks (the paper's optimum).
cfg = ModelConfig(
    name="quickstart-mod",
    n_layers=6,
    d_model=128,
    d_ff=256,
    vocab=512,
    max_seq_len=128,
    dtype="float32",
    attn=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=32),
    mod=MoDConfig(enabled=True, capacity_ratio=0.125, every=2, round_to=1,
                  gate="sigmoid", sampling="predictor"),
)

STEPS = int(os.environ.get("QUICKSTART_STEPS", "60"))
tcfg = TrainConfig(global_batch=8, seq_len=128,
                   optim=OptimConfig(lr=1e-3, warmup_steps=10, total_steps=STEPS))

# 2. Data + state + step.
data = SyntheticLM(cfg.vocab, tcfg.seq_len, seed=0)
state = make_train_state(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

print(f"params: {sum(x.size for x in jax.tree.leaves(state['params'])):,}")
for i in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in data.batch(i, tcfg.global_batch).items()}
    state, m = step(state, batch)
    if (i + 1) % 10 == 0:
        print(
            f"step {i+1:3d}  ce={float(m['ce']):.3f}  "
            f"router>0.5: {float(m['mod/frac_above_half']):.3f} "
            f"(target {cfg.mod.capacity_ratio})  "
            f"predictor acc: {float(m['mod/predictor_acc']):.3f}"
        )

# 3. Sample autoregressively — routing decisions are causal (predictor).
prompt = jnp.asarray(data.batch(10_000, 1)["tokens"][:, :16])
out = greedy_generate(state["params"], cfg, prompt, n_tokens=16)
print("prompt:      ", prompt[0].tolist())
print("continuation:", out[0, 16:].tolist())
