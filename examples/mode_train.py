"""MoDE (paper §4.3): composing Mixture-of-Depths with Mixture-of-Experts.

Trains three matched models — a token-choice MoE baseline, *staged* MoDE
(MoD routing around blocks whose MLP is the MoE), and *integrated* MoDE
(no-op experts inside the MoE router) — and compares losses, mirroring
paper Fig. 7 at CPU scale.

  PYTHONPATH=src python examples/mode_train.py
"""
from benchmarks.common import tiny_config, train_bench
from repro.config import MoEConfig

STEPS = 80

moe = MoEConfig(enabled=True, n_experts=4, top_k=2, d_ff_expert=128)
print("1/3 MoE baseline...")
base = train_bench(tiny_config(mod=False, moe=moe, n_layers=4), steps=STEPS)
print(f"    eval ce {base['eval_ce']:.4f}")

print("2/3 staged MoDE (MoD around MoE blocks)...")
staged = train_bench(tiny_config(mod=True, moe=moe, n_layers=4), steps=STEPS)
print(f"    eval ce {staged['eval_ce']:.4f}")

print("3/3 integrated MoDE (no-op experts)...")
moe_i = MoEConfig(enabled=True, n_experts=4, top_k=2, d_ff_expert=128,
                  mode_variant="integrated", n_noop_experts=2)
integrated = train_bench(tiny_config(mod=False, moe=moe_i, n_layers=4), steps=STEPS)
print(f"    eval ce {integrated['eval_ce']:.4f}")

print("\nsummary (lower is better):")
print(f"  moe baseline     {base['eval_ce']:.4f}  ({base['steps_per_s']:.2f} steps/s)")
print(f"  staged MoDE      {staged['eval_ce']:.4f}  ({staged['steps_per_s']:.2f} steps/s)")
print(f"  integrated MoDE  {integrated['eval_ce']:.4f}  ({integrated['steps_per_s']:.2f} steps/s)")
